"""Batched serving driver: prefill + decode with the VEXP attention stack.

Continuous-batching-lite: a request queue is packed into fixed-shape decode
batches (padded slots), prefill and decode are separate jit programs (the
production split — prefill is compute-bound, decode is memory-bound), and
the KV cache sharding follows distributed.sharding.cache_specs.
"""

from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.distributed import sharding as shd
from repro.runtime import ExecPolicy, resolve_policy
from .mesh import make_host_mesh


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    out: list = field(default_factory=list)


class Server:
    """Serving engine bound to one ExecPolicy.

    The policy (exp backend, kernel backend, block sizes) is resolved once
    at construction — config fields, then REPRO_* env vars, then the
    ``policy=`` override — and closed over by the prefill/decode jit
    programs, so a policy switch is a new Server, never a silent retrace.
    """

    def __init__(self, cfg, params, *, max_batch=4, max_seq=512, mesh=None,
                 policy: ExecPolicy | None = None):
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_seq = max_batch, max_seq
        self.mesh = mesh or make_host_mesh()
        self.policy = policy if policy is not None else resolve_policy(cfg)
        pol = self.policy
        self._prefill = jax.jit(
            lambda p, b: api.prefill(p, cfg, b, policy=pol))
        self._decode = jax.jit(
            lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos,
                                                 policy=pol))

    def run(self, requests: list[Request]) -> list[Request]:
        """Greedy decode, batch-padded. Requests must share prompt length
        (the packer pads); returns requests with .out filled."""
        done = []
        with self.mesh:
            for i in range(0, len(requests), self.max_batch):
                chunk = requests[i:i + self.max_batch]
                done.extend(self._run_batch(chunk))
        return done

    def _run_batch(self, chunk):
        b = len(chunk)
        plen = max(len(r.prompt) for r in chunk)
        toks = np.zeros((b, plen), np.int32)
        for j, r in enumerate(chunk):
            toks[j, plen - len(r.prompt):] = r.prompt     # left-pad
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(toks)})
        if cache is None:                                  # ssm prefill
            cache = api.init_cache(self.cfg, b, self.max_seq)
        cache = self._grow_cache(cache, b, plen)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        max_new = max(r.max_new for r in chunk)
        for step in range(max_new):
            for j, r in enumerate(chunk):
                if step < r.max_new:
                    r.out.append(int(tok[j, 0]))
            logits, cache = self._decode(self.params, tok, cache,
                                         jnp.int32(plen + step))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        return chunk

    def _grow_cache(self, cache, b, plen):
        """Pad prefill KV caches out to max_seq slots."""
        cfg = self.cfg
        if cfg.family == "ssm":
            return cache
        target = min(self.max_seq,
                     cfg.sliding_window or self.max_seq)

        flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
        out = []
        for path, x in flat:
            name = str(getattr(path[-1], "key", ""))
            if name in ("k", "v") and x.shape[-3] < target:
                pad = [(0, 0)] * x.ndim
                pad[-3] = (0, target - x.shape[-3])
                x = jnp.pad(x, pad)
            out.append(x)
        return jax.tree_util.tree_unflatten(treedef, out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--exp-backend", default=None,
                    choices=["exact", "vexp", "vexp_hw"],
                    help="exponential backend (default: config/env)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "reference", "xla"],
                    help="kernel backend (default: config/env)")
    ap.add_argument("--autotune", action="store_true",
                    help="autotune kernel block sizes per shape bucket")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = resolve_policy(cfg, exp_backend=args.exp_backend,
                            kernel_backend=args.kernel_backend,
                            autotune=args.autotune or None)
    print(f"[serve] policy: {policy.describe()}")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    server = Server(cfg, params, policy=policy)
    rng = np.random.default_rng(0)
    reqs = [Request(i, rng.integers(0, cfg.vocab, (args.prompt_len,),
                                    dtype=np.int32), args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    out = server.run(reqs)
    dt = time.perf_counter() - t0
    ntok = sum(len(r.out) for r in out)
    print(f"served {len(out)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s)")
    for r in out[:3]:
        print(f"  req {r.rid}: {r.out[:8]}...")


if __name__ == "__main__":
    main()
