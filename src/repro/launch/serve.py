"""Slot-level continuous-batching serving engine on the VEXP stack.

The engine replaces the old fixed-shape chunk loop (which left-padded
prompts with token 0, attended the padding during prefill, and passed one
scalar ``cache_len`` to decode — silently corrupting every request shorter
than the longest in its batch). The structural fix is per-slot state:

* a fixed pool of ``max_batch`` KV-cache slots per policy group, allocated
  once at ``max_seq`` (or the sliding window — windowed archs serve
  through the same fused flash-decode kernel as linear ones now that it
  understands windows and both cache layouts; no reference fallback);
* ragged admission — queued requests are right-padded to a pow2 length
  bucket, prefilled as one batch with per-request ``prompt_len`` (padding
  masked out of attention, pad K/V rows zeroed), and their real cache rows
  are written into freed slots;
* per-slot decode — one fixed-shape ``(max_batch, 1)`` decode program per
  policy group with a per-slot ``(B,)`` position vector, so each slot
  advances at its own length (the kernels mask each row against its own
  ``cache_len``);
* continuous batching — a slot is freed the step its request finishes
  (``max_new`` reached or the linear cache exhausted) and the next queued
  request is admitted mid-decode, instead of burning steps on dead slots.

Per-request execution policies: requests carry a ``group`` name and each
group owns one ExecPolicy, one cache pool and exactly one decode
executable (PR 1's one-executable-per-policy contract), so eval traffic
can run ``exact`` numerics while bulk traffic runs ``vexp`` without
contaminating each other's batches or caches.

The decode hot loop is collective- and copy-minimal:

* **SPMD wiring** — when ``distributed.sharding.decode_kv_axis`` reports
  a sequence-sharded decode cache on the serving mesh, each
  pallas-backend group's decode step is ONE ``shard_map`` program built
  at engine startup: per layer, the token's K/V land on the owning shard
  (drop-mode scatter), every shard sweeps its slice in
  partial-statistics mode, and the statistics fold through the policy's
  ``merge_strategy`` — "packed" is a single all_gather of the contiguous
  (acc | m | l) tile, i.e. exactly one collective per layer.
* **Donated step** — the KV cache and the per-slot position vector are
  donated through the decode program (buffers reused in place: no cache
  re-allocation per step), positions advance device-side (`pos + live`),
  and emitted tokens stay device-resident — a steady-state decode step
  performs zero host syncs and zero host->device transfers.
"""

from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import api
from repro.models.transformer import cache_seq_axis
from repro.runtime import ExecPolicy, resolve_policy, parse_policy_groups
from .mesh import make_host_mesh


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    group: str = "default"              # policy group (Server.policy_groups)
    out: list = field(default_factory=list)
    finish_reason: Optional[str] = None  # "max_new" | "length_cap"
    # wall-clock latency markers (filled by the engine)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0


def _len_bucket(n: int, cap: int) -> int:
    """Pow2-rounded prefill length (>=8) so ragged admission shares a small
    set of prefill executables; capped at the cache's sequence capacity."""
    b = 8
    while b < n:
        b *= 2
    return min(b, cap)


# (repr(cfg), policy, kv_axis[, mesh]) -> (prefill_fn, prefill_plain_fn,
# decode_fn). jax.jit caches per function object, so the jitted closures
# must outlive any one Server — otherwise every server restart recompiles
# the programs. Greedy serving never reads logits on the host, so all
# programs return argmaxed (B, 1) token ids — one fused executable per
# step, no eager argmax dispatches.
#
# decode_fn(params, last, cache, pos, live) -> (next, cache, pos + live):
# the KV cache and the per-slot position vector are DONATED (their input
# buffers are reused for the outputs), so a decode step allocates no new
# cache and the slot positions advance device-side — the hot loop performs
# zero host->device transfers and zero host syncs.
_PROGRAM_CACHE: dict = {}


def _programs(cfg, policy, mesh=None, kv_axis=None, decode_policy=None):
    # decode_policy: the (possibly merge-strategy-autotuned) policy the
    # decode program is built against; prefill keeps the group policy so
    # its in-jit autotune cache reads stay live.
    dpol = policy if decode_policy is None else decode_policy
    key = (repr(cfg), policy, dpol, kv_axis,
           mesh if kv_axis is not None else None)
    if key not in _PROGRAM_CACHE:
        pol = policy

        def prefill_fn(p, toks, plens):
            logits, cache = api.prefill(
                p, cfg, {"tokens": toks, "prompt_len": plens}, policy=pol)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        def prefill_plain_fn(p, toks):
            # every row full-length: no padding mask to apply (the common
            # uniform-traffic admission; skips the ragged machinery)
            logits, cache = api.prefill(p, cfg, {"tokens": toks},
                                        policy=pol)
            return jnp.argmax(logits, -1).astype(jnp.int32), cache

        if kv_axis is None:
            def decode_fn(p, t, c, pos, live):
                logits, cache = api.decode_step(p, cfg, t, c, pos,
                                                policy=dpol)
                return (jnp.argmax(logits, -1).astype(jnp.int32), cache,
                        pos + live)

            decode = jax.jit(decode_fn, donate_argnums=(2, 3))
        else:
            # Sequence-sharded decode: ONE shard_map program per policy
            # group, built here at engine startup — the fused
            # partial-statistics path instead of GSPMD lowering. The
            # cache lives (and stays) sharded along its S axis; each
            # layer's shard statistics fold through the policy's merge
            # strategy ("packed": one collective per layer).
            from jax.sharding import PartitionSpec as P
            from repro.distributed.compression import shard_map
            from repro.distributed.sharding import serve_cache_sharding
            from repro.models.transformer import decode_step_sharded
            # one source of truth for the pool placement: the program's
            # in/out specs are the spec of the sharding the engine
            # allocates the pool under.
            cspec = {name: s.spec for name, s in
                     serve_cache_sharding(cfg, mesh, kv_axis).items()}

            def decode_local(p, t, c, pos, live):
                logits, c = decode_step_sharded(p, cfg, t, c, pos,
                                                policy=dpol,
                                                seq_axis=kv_axis)
                return (jnp.argmax(logits, -1).astype(jnp.int32), c,
                        pos + live)

            decode = jax.jit(
                shard_map(decode_local, mesh=mesh,
                          in_specs=(P(), P(), cspec, P(), P()),
                          out_specs=(P(), cspec, P())),
                donate_argnums=(2, 3))

        _PROGRAM_CACHE[key] = (jax.jit(prefill_fn),
                               jax.jit(prefill_plain_fn),
                               decode)
    return _PROGRAM_CACHE[key]


def _autotune_warmup(cfg, policy, max_batch, cache_s, mesh=None,
                     kv_axis=None):
    """Eagerly tune the decode-attention block size for this group's decode
    shape. Timing is meaningless inside the jitted decode program (tracers,
    not device work), so the tuner only ever *reads* its cache there — this
    one eager call at the real (max_batch, cache_s) shape times the
    candidates, memoizes the winner for the jit path to pick up, and
    persists it to disk so the next server start skips even this.

    On a sequence-sharded group it additionally times the two collective
    merge strategies (packed single-collective vs pmax+2×psum) at the
    group's exact decode shape and returns the policy with the winner
    baked in (the shard_map decode program takes the policy statically,
    so the engine must resolve it before building the program). Returns
    the — possibly tuned — policy.
    """
    if not policy.autotune or policy.kernel_backend != "pallas":
        return policy
    from repro.kernels.dispatch import dispatch, autotune_policy
    lay = cfg.kv_cache_layout
    kv_shape = ((max_batch, cfg.n_kv_heads, cache_s, cfg.hd)
                if lay == "bhsd" else
                (max_batch, cache_s, cfg.n_kv_heads, cfg.hd))
    q = jnp.zeros((max_batch, 1, cfg.n_heads, cfg.hd),
                  jnp.dtype(cfg.compute_dtype))
    kv = jnp.zeros(kv_shape, jnp.bfloat16)      # init_cache's dtype
    clen = jnp.full((max_batch,), cache_s, jnp.int32)
    dispatch("decode_attention", policy)(q, kv, kv, clen, layout=lay,
                                         policy=policy)
    if kv_axis is None:
        return policy
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.kernels.decode_attention.ops import _sharded_program
    from repro.models.transformer import cache_seq_axis as _csa
    spec = [None] * 4
    spec[_csa(lay, stacked=False)] = kv_axis
    kvs = jax.device_put(kv, NamedSharding(mesh, P(*spec)))
    return autotune_policy(
        "decode_attention_sharded", policy,
        lambda p: _sharded_program(mesh, kv_axis, None, None, lay,
                                   p)(q, kvs, kvs, clen),
        q, kvs)


class _Group:
    """One policy group: ExecPolicy + cache-slot pool + jit programs.

    Greedy scheduling decisions depend only on token *counts* (max_new,
    cache capacity), never on token values — so emitted tokens stay on
    device as (B, 1) argmax arrays (computed inside the jitted programs)
    and each request's token ids are materialized once, when it finishes.
    The decode loop therefore never blocks on a device->host sync and
    JAX's async dispatch pipelines the steps exactly like the fixed-shape
    driver it replaced.
    """

    def __init__(self, cfg, params, policy, max_batch, cache_s, *,
                 mesh=None, kv_axis=None):
        self.cfg, self.params, self.policy = cfg, params, policy
        self.max_batch, self.cache_s = max_batch, cache_s
        self.mesh, self.kv_axis = mesh, kv_axis
        self.queue: deque = deque()
        self.reqs: list = [None] * max_batch
        self.lens = np.zeros(max_batch, np.int64)   # valid cache positions
        self.ntok = np.zeros(max_batch, np.int64)   # tokens emitted per slot
        # Device-side slot state: last tokens, per-slot decode positions and
        # a 0/1 liveness vector. The decode program advances pos by live
        # in-place (donated), so the steady-state loop never ships a
        # position vector host->device; lens/ntok above are host *mirrors*
        # maintained from scheduling events alone (never read back).
        self.last = jnp.zeros((max_batch, 1), jnp.int32)
        self.pos_dev = jnp.zeros((max_batch,), jnp.int32)
        self.live_dev = jnp.zeros((max_batch,), jnp.int32)
        self._repl = None           # mesh-replicated sharding (SPMD groups)
        self._cache_shard = None    # sharded cache placement (SPMD groups)
        if kv_axis is not None:
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed.sharding import serve_cache_sharding
            self._repl = NamedSharding(mesh, P())
            self._cache_shard = serve_cache_sharding(cfg, mesh, kv_axis)
            # decode runs over the mesh; prefill stays on the default
            # device (its outputs are re-placed at admission).
            self.params_decode = jax.device_put(params, self._repl)
            self.last, self.pos_dev, self.live_dev = jax.device_put(
                (self.last, self.pos_dev, self.live_dev), self._repl)
        else:
            self.params_decode = params
        self.cache = None                           # allocated on first admit
        self.decode_steps = 0
        self.decode_s: list = []    # per-step *dispatch* wall time (async:
                                    # compute overlaps; see req_lat for real
                                    # latency, measured at the finish sync)
        self.req_lat: list = []     # per-request submit->done wall latency
        self._toks: dict = {}                       # slot -> [(B,1) arrays]
        decode_policy = _autotune_warmup(cfg, policy, max_batch, cache_s,
                                         mesh, kv_axis)
        (self._prefill, self._prefill_plain,
         self._decode) = _programs(cfg, policy, mesh, kv_axis,
                                   decode_policy)

    # ------------------------------------------------------------ admission

    def admit(self, admit_log=None):
        """Fill freed slots from the queue with one ragged batched prefill."""
        free = [j for j in range(self.max_batch) if self.reqs[j] is None]
        take = []
        while free and self.queue:
            take.append((free.pop(0), self.queue.popleft()))
        if not take:
            return
        slots = np.array([j for j, _ in take])
        sp = _len_bucket(max(len(r.prompt) for _, r in take), self.cache_s)
        # prefill always runs at the full pool width so admitting 1 or
        # max_batch requests hits the same executable per length bucket;
        # rows without an admitted request are dummies (length-1, ignored).
        toks = np.zeros((self.max_batch, sp), np.int32)
        plens = np.ones(self.max_batch, np.int32)
        for j, r in take:
            toks[j, :len(r.prompt)] = r.prompt
            plens[j] = len(r.prompt)
        full = len(take) == self.max_batch
        if (full and all(len(r.prompt) == sp for _, r in take)
                and self.policy.kernel_backend != "pallas"):
            # uniform exact-bucket wave: no padding exists, skip the mask.
            # (Not under a pallas policy: the ragged path demotes pallas
            # flash-attention to the reference scan, so the fast path
            # would prefill through a different implementation than solo
            # serving and could flip a near-tie greedy argmax.)
            first, pref = self._prefill_plain(self.params, jnp.asarray(toks))
        else:
            first, pref = self._prefill(self.params, jnp.asarray(toks),
                                        jnp.asarray(plens))
        if self._repl is not None:
            # SPMD group: prefill ran on the default device; move its
            # outputs onto the decode mesh (tokens replicated, cache rows
            # merged into the mesh-sharded pool below).
            first = jax.device_put(first, self._repl)
        # write admitted rows into the persistent slot pool; the sequence
        # axis is resolved from the cache layout — "bshd" stacked caches
        # are (L, B, S, Hkv, hd), "bhsd" are (L, B, Hkv, S, hd).
        ax = cache_seq_axis(self.cfg.kv_cache_layout)
        if full:
            # whole pool admitted at once: the pool cache is just the
            # prefill cache padded out to capacity (no scatter, no zeros)
            pad = [(0, 0)] * pref["k"].ndim
            pad[ax] = (0, self.cache_s - sp)
            self.cache = {n: jnp.pad(pref[n], pad) for n in ("k", "v")}
            if self._cache_shard is not None:
                self.cache = jax.device_put(self.cache, self._cache_shard)
            self.last = first
        else:
            if self.cache is None:
                self.cache = api.init_cache(self.cfg, self.max_batch,
                                            self.cache_s)
                if self._cache_shard is not None:
                    self.cache = jax.device_put(self.cache,
                                                self._cache_shard)
            idx = [slice(None)] * self.cache["k"].ndim
            idx[1] = slots
            idx[ax] = slice(0, sp)
            idx = tuple(idx)
            row = (slice(None), slots)
            for name in ("k", "v"):
                rows = pref[name][row]
                if self._repl is not None:
                    rows = jax.device_put(rows, self._repl)
                self.cache[name] = self.cache[name].at[idx].set(rows)
            self.last = self.last.at[slots].set(first[slots])
        # one batched device-side slot-state update per admission wave
        sl = jnp.asarray(slots)
        self.pos_dev = self.pos_dev.at[sl].set(
            jnp.asarray([len(r.prompt) for _, r in take], jnp.int32))
        self.live_dev = self.live_dev.at[sl].set(1)
        now = time.perf_counter()
        for j, r in take:
            self.reqs[j] = r
            self.lens[j] = len(r.prompt)
            self.ntok[j] = 1
            self._toks[j] = [first]
            r.t_first = now
            if admit_log is not None:
                admit_log.append(r.rid)
            if self.ntok[j] >= r.max_new:
                self._finish(j, "max_new")

    # --------------------------------------------------------------- decode

    def decode_once(self):
        """One batched decode step over the live slots (no-op when idle)."""
        if self.cfg.sliding_window is None:
            # a linear cache is exhausted when the next write would fall
            # past the last slot — stop the request instead of letting a
            # clamped write silently overwrite the final cache row.
            for j in range(self.max_batch):
                if self.reqs[j] is not None and self.lens[j] >= self.cache_s:
                    self._finish(j, "length_cap")
        live = [j for j in range(self.max_batch) if self.reqs[j] is not None]
        if not live:
            return
        # dead slots decode their stale token at position 0: harmless (the
        # slot has no request, and admission prefill overwrites row 0
        # before the slot is read again). The position vector lives on
        # device (live slots advance by +1 inside the donated program), so
        # the hot loop ships nothing host->device and syncs on nothing.
        t0 = time.perf_counter()
        nxt, self.cache, self.pos_dev = self._decode(
            self.params_decode, self.last, self.cache, self.pos_dev,
            self.live_dev)
        self.last = nxt
        self.decode_s.append(time.perf_counter() - t0)
        self.decode_steps += 1
        for j in live:
            self.lens[j] += 1
            self.ntok[j] += 1
            self._toks[j].append(nxt)
            if self.ntok[j] >= self.reqs[j].max_new:
                self._finish(j, "max_new")

    def _finish(self, j, reason):
        r = self.reqs[j]
        # one device->host sync per finished request: gather its column
        # from the logged per-step argmax vectors.
        toks = np.asarray(jnp.stack(self._toks.pop(j)))[:, j, 0]
        r.out.extend(int(t) for t in toks)
        r.finish_reason = reason
        r.t_done = time.perf_counter()   # after the sync: true completion
        self.req_lat.append(r.t_done - r.t_submit)
        self.reqs[j] = None          # slot freed; next admit() reuses it
        # park the slot device-side (live=0 excludes it from position
        # advance; pos=0 matches the dead-slot write convention)
        self.live_dev = self.live_dev.at[j].set(0)
        self.pos_dev = self.pos_dev.at[j].set(0)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or any(r is not None for r in self.reqs)


class Server:
    """Slot-level continuous-batching server.

    One ExecPolicy per *group* (default: a single group from the usual
    resolution chain), each with its own ``max_batch``-slot cache pool and
    exactly one decode executable. ``run(requests)`` drives admission and
    decode until every request is finished.

    Transformer-family configs only (dense / moe / vlm): ssm and hybrid
    recurrences have no per-slot cache positions yet — serve those one
    batch at a time through ``models.api`` directly.
    """

    def __init__(self, cfg, params, *, max_batch=4, max_seq=512, mesh=None,
                 policy: ExecPolicy | None = None,
                 policy_groups: Optional[dict] = None,
                 kv_mode: str = "auto"):
        if cfg.family in ("ssm", "hybrid", "audio"):
            raise NotImplementedError(
                f"the slot engine serves transformer-family configs; "
                f"{cfg.family!r} has no per-slot cache positions")
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_seq = max_batch, max_seq
        self.mesh = mesh or make_host_mesh()
        self.policy = policy if policy is not None else resolve_policy(cfg)
        if self.policy.autotune or (policy_groups and any(
                p.autotune for p in policy_groups.values())):
            # warm the block-size tuner from the on-disk cache: a restart
            # on the same device kind reuses every previously-timed winner
            # instead of re-timing candidates on the first wave.
            from repro.kernels import dispatch as _dispatch
            n = _dispatch.load_autotune_cache()
            if n:
                print(f"[serve] autotune: {n} block-size winners loaded "
                      f"from {_dispatch.autotune_cache_path()}")
        self.cache_s = min(max_seq, cfg.sliding_window or max_seq)
        # Serve-loop SPMD wiring: when the cache placement rules report a
        # sequence-sharded decode cache on this mesh, pallas-backend groups
        # route their decode step through the fused sharded path (one
        # shard_map program per group, built once here at startup) instead
        # of GSPMD-lowering the unsharded program. Windowed archs keep the
        # GSPMD path (the ring-buffer wrap write straddles shards).
        self.kv_axis = None
        if cfg.sliding_window is None:
            from repro.distributed.sharding import decode_kv_axis
            ax = decode_kv_axis(cfg, self.mesh, max_batch, kv_mode=kv_mode)
            if (ax is not None and self.mesh.shape[ax] > 1
                    and self.cache_s % self.mesh.shape[ax] == 0):
                self.kv_axis = ax
        groups = dict(policy_groups) if policy_groups else {}
        if "default" not in groups:
            groups["default"] = self.policy
        self.policy_groups = groups
        self._groups = {
            name: _Group(cfg, params, pol, max_batch, self.cache_s,
                         mesh=self.mesh,
                         kv_axis=(self.kv_axis
                                  if pol.kernel_backend == "pallas"
                                  else None))
            for name, pol in groups.items()}
        self.admit_log: list = []    # rids in admission order (tests/debug)

    # ------------------------------------------------------------ scheduling

    def submit(self, r: Request) -> None:
        if r.group not in self._groups:
            raise ValueError(f"unknown policy group {r.group!r}; "
                             f"have {sorted(self._groups)}")
        plen = len(r.prompt)
        if plen < 1:
            raise ValueError(f"request {r.rid}: empty prompt")
        if plen > self.cache_s:
            raise ValueError(
                f"request {r.rid}: prompt of {plen} tokens exceeds the "
                f"cache capacity ({self.cache_s})")
        if r.max_new < 1:
            raise ValueError(f"request {r.rid}: max_new must be >= 1")
        r.t_submit = time.perf_counter()
        self._groups[r.group].queue.append(r)

    def step(self) -> bool:
        """One scheduler tick: admit into freed slots, then one decode step
        per busy group. Returns True while any work remains."""
        for g in self._groups.values():
            g.admit(self.admit_log)
        for g in self._groups.values():
            g.decode_once()
        return any(g.busy for g in self._groups.values())

    def drain(self) -> None:
        with self.mesh:
            while self.step():
                pass

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve to completion; returns the requests with .out filled."""
        for r in requests:
            self.submit(r)
        self.drain()
        return requests

    # ------------------------------------------------------------ telemetry

    def stats(self) -> dict:
        """Per-group decode-step count and request-latency tail (submit ->
        tokens materialized; measured at a real device sync, unlike the
        async per-step dispatch times)."""
        out = {}
        for name, g in self._groups.items():
            lat = sorted(g.req_lat)
            out[name] = {
                "decode_steps": g.decode_steps,
                "p50_req_s": lat[len(lat) // 2] if lat else 0.0,
                "p95_req_s": lat[min(int(len(lat) * 0.95),
                                     len(lat) - 1)] if lat else 0.0,
                "policy": g.policy.describe(),
                "kv_axis": g.kv_axis,
            }
        return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="draw prompt lengths in [4, --prompt-len] instead "
                         "of a uniform length (exercises ragged admission)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--exp-backend", default=None,
                    choices=["exact", "vexp", "vexp_hw"],
                    help="exponential backend (default: config/env)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "reference", "xla"],
                    help="kernel backend (default: config/env)")
    ap.add_argument("--policy-groups", default=None,
                    help='per-request policy groups, e.g. '
                         '"eval=exact,bulk=vexp" (requests are assigned '
                         'round-robin); omit for a single default group')
    ap.add_argument("--autotune", action="store_true",
                    help="autotune kernel block sizes per shape bucket")
    ap.add_argument("--kv-mode", default="auto",
                    choices=["auto", "seq", "batch"],
                    help='decode-cache placement: "seq" shards the KV '
                         'sequence dim over the mesh\'s model axis '
                         '(sequence-parallel fused decode); "auto" follows '
                         'distributed.sharding.cache_specs')
    ap.add_argument("--mesh-model", type=int, default=None,
                    help="model-axis size of the serving mesh (default: "
                         "all devices when --kv-mode seq, else 1)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = resolve_policy(cfg, exp_backend=args.exp_backend,
                            kernel_backend=args.kernel_backend,
                            autotune=args.autotune or None)
    groups = None
    if args.policy_groups:
        groups = parse_policy_groups(args.policy_groups, cfg, base=policy)
    print(f"[serve] policy: {policy.describe()}")
    if groups:
        for name, pol in groups.items():
            print(f"[serve]   group {name}: {pol.describe()}")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_model = args.mesh_model or (len(jax.devices())
                                  if args.kv_mode == "seq" else 1)
    mesh = make_host_mesh(1, n_model)
    server = Server(cfg, params, max_batch=args.max_batch,
                    max_seq=args.max_seq, mesh=mesh, policy=policy,
                    policy_groups=groups, kv_mode=args.kv_mode)
    print(f"[serve] mesh {dict(server.mesh.shape)}; sharded decode axis: "
          f"{server.kv_axis}")
    rng = np.random.default_rng(0)
    names = sorted(groups) if groups else ["default"]
    reqs = []
    for i in range(args.requests):
        plen = (int(rng.integers(4, args.prompt_len + 1))
                if args.mixed_lengths else args.prompt_len)
        reqs.append(Request(i, rng.integers(0, cfg.vocab, (plen,),
                                            dtype=np.int32),
                            args.max_new, group=names[i % len(names)]))
    t0 = time.perf_counter()
    out = server.run(reqs)
    dt = time.perf_counter() - t0
    ntok = sum(len(r.out) for r in out)
    print(f"served {len(out)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s)")
    for name, s in server.stats().items():
        print(f"  group {name}: {s['decode_steps']} decode steps, "
              f"request latency p50 {s['p50_req_s'] * 1e3:.1f}ms "
              f"p95 {s['p95_req_s'] * 1e3:.1f}ms")
    for r in out[:3]:
        print(f"  req {r.rid} [{r.group}] len={len(r.prompt)}: "
              f"{r.out[:8]}... ({r.finish_reason})")


if __name__ == "__main__":
    main()
