"""Slot-level continuous-batching serving engine on the VEXP stack.

The engine replaces the old fixed-shape chunk loop (which left-padded
prompts with token 0, attended the padding during prefill, and passed one
scalar ``cache_len`` to decode — silently corrupting every request shorter
than the longest in its batch). The structural fix is per-slot state:

* a fixed pool of ``max_batch`` decode-state slots per policy group — a
  ``models.decode_state.DecodeState`` (KV cache + positions for the
  transformer families, batched per-layer ``(h, conv)`` snapshots for
  ssm, a mixed per-period state for hybrid), allocated once at
  ``max_seq`` (or the sliding window). The engine is state-kind-agnostic:
  admission, decode, freeing, donation and device-side liveness all go
  through the protocol, and the engine never branches on the model
  family;
* ragged admission — queued requests are right-padded to the state's
  prefill width (a pow2 length bucket, or the fixed window for hybrid),
  prefilled as one batch with per-request ``prompt_len`` (padding masked
  out of attention / dt-masked out of the recurrences), and their real
  rows are written into freed slots — KV rows by cache scatter,
  recurrent states at each row's *last real token*;
* per-slot decode — one fixed-shape ``(max_batch, 1)`` decode program per
  policy group with a per-slot ``(B,)`` position vector, so each slot
  advances at its own length (the kernels mask each row against its own
  ``cache_len``; recurrences carry position in their state);
* continuous batching — a slot is freed the step its request finishes
  (``max_new`` reached or a linear cache exhausted), its state is reset
  through the protocol (stale recurrent ``h``/``conv`` must not bleed
  into the next occupant), and the next queued request is admitted
  mid-decode instead of burning steps on dead slots.

Per-request execution policies: requests carry a ``group`` name and each
group owns one ExecPolicy, one state pool and exactly one decode
executable (PR 1's one-executable-per-policy contract), so eval traffic
can run ``exact`` numerics while bulk traffic runs ``vexp`` without
contaminating each other's batches or caches — including the recurrent
families, whose RG-LRU / SSD gate exponentials follow the same policy.

The decode hot loop is collective- and copy-minimal:

* **SPMD wiring** — when the state pool reports the capability
  (``DecodeState.supports_seq_sharding``; linear KV caches only) and
  ``distributed.sharding.decode_kv_axis`` reports a sequence-sharded
  decode cache on the serving mesh, each pallas-backend group's decode
  step is ONE ``shard_map`` program built at engine startup: per layer,
  the token's K/V land on the owning shard (drop-mode scatter), every
  shard sweeps its slice in partial-statistics mode, and the statistics
  fold through the policy's ``merge_strategy`` — "packed" is a single
  all_gather of the contiguous (acc | m | l) tile, i.e. exactly one
  collective per layer.
* **Donated step** — the state pool and the per-slot position vector are
  donated through the decode program (buffers reused in place: no state
  re-allocation per step), positions advance device-side (`pos + live`),
  and emitted tokens stay device-resident — a steady-state decode step
  performs zero host syncs and zero host->device transfers.

Chunked prefill (``ExecPolicy.prefill_chunk > 0``): instead of one
monolithic admission wave per prefill bucket, the scheduler becomes
two-queue — each engine tick runs one decode step plus AT MOST ONE
bounded prefill chunk. Queued prompts are admitted per-request into
freed slots (``DecodeState.begin_chunk``) and stream into their slot
``chunk_width`` tokens per tick through one fixed-shape resumable
program (``prefill_chunk_into``: rows not prefilling this tick carry
``clens == 0`` and pass through bit-untouched), so a long prompt never
stalls decode for longer than one chunk and TTFT for short requests no
longer queues behind long prompts' prefill. Mid-prefill slots are dead
to decode (``live == 0``; their position is pinned at the prompt length
by ``begin_chunk``), and the completion tick flips them live with no
extra device traffic. The chunk-step path keeps the decode loop's
zero-host-sync discipline: chunks are dispatched async, and TTFT /
per-chunk wall time are sampled only at scheduling events.

Fault tolerance (PR 9): requests carry deadlines and cooperative
cancellation (``reap`` drops them at scheduling events and releases
their slot/pages through ``DecodeState.abort_chunk`` / ``reset_slots``);
a seeded ``ft.inject.FaultInjector`` can be threaded through the engine
(``Server(injector=...)``) to force OutOfBlocks, step failures, slot
poisoning, straggler chunks and prefix corruption — off by default and
guarded at scheduling events only, so the hot loop stays sync-free; the
decode programs' finite-logits sentinel (token ``-1``) quarantines
poisoned slots at finish instead of streaming garbage; and a hysteretic
degradation ladder sheds load under sustained pool pressure (L1 halves
the prefill chunk width, L2 drops ``--degrade-groups`` to the policy's
``degrade_exp_backend``), restoring when pressure clears.
"""

from __future__ import annotations

import argparse
import os
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import numpy as np
import jax
import jax.numpy as jnp

from repro.analysis.registry import hot_path
from repro.configs import get_config
from repro.ft import (FAULT_SEED_ENV, FaultInjector, InjectedFault,
                      default_chaos_rates)
from repro.models import api
from repro.models.block_pool import OutOfBlocks
from repro.models.decode_state import (decode_state_for, _len_bucket,  # noqa: F401  (re-export)
                                       SPEC_PAD)
from repro.runtime import ExecPolicy, resolve_policy, parse_policy_groups
from .mesh import make_host_mesh

# Bounded admission retry: with work in flight a rejected admission just
# waits for the next tick (pages WILL free); with nothing in flight no
# page can ever free on its own, so the engine retries with exponential
# backoff a bounded number of times — absorbing transient/injected
# rejections — then sheds the head request instead of spinning forever
# (the old behavior) or crashing the loop (the other old behavior).
MAX_ADMIT_RETRIES = 8
ADMIT_BACKOFF_S = 0.002
ADMIT_BACKOFF_CAP_S = 0.05
# A step-fault victim is re-queued and re-served this many times before
# the engine concludes the request itself kills the step and sheds it.
MAX_STEP_RETRIES = 3
# Degradation-ladder hysteresis, in scheduler ticks: escalation needs
# DEGRADE_AFTER consecutive pressured ticks, restoration RESTORE_AFTER
# clear ones — sticky both ways so a boundary workload cannot thrash
# the (cached) program swaps.
PRESSURE_HIGH = 0.85
DEGRADE_AFTER = 3
RESTORE_AFTER = 8


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                  # (S,) int32
    max_new: int = 16
    group: str = "default"              # policy group (Server.policy_groups)
    out: list = field(default_factory=list)
    # "max_new" | "length_cap" on success; "cancelled" | "deadline" |
    # "quarantined" | "failed" when the engine stopped the request
    # without materializing tokens
    finish_reason: Optional[str] = None
    # wall-clock latency markers (filled by the engine)
    t_submit: float = 0.0
    t_first: float = 0.0
    t_done: float = 0.0
    # ---- lifecycle ----
    deadline_s: Optional[float] = None  # TTL from submit (None = server's)
    cancel_requested: bool = False
    retries: int = 0                    # step-fault re-serves so far

    def cancel(self):
        """Cooperative cancellation: flags the request; the engine honors
        it at the next scheduling event (``reap``), releasing the slot
        and any pages/prefix refs it holds."""
        self.cancel_requested = True


class _Group:
    """One policy group: ExecPolicy + DecodeState slot pool + scheduling.

    Greedy scheduling decisions depend only on token *counts* (max_new,
    cache capacity), never on token values — so emitted tokens stay on
    device as (B, 1) argmax arrays (computed inside the jitted programs)
    and each request's token ids are materialized once, when it finishes.
    The decode loop therefore never blocks on a device->host sync and
    JAX's async dispatch pipelines the steps exactly like the fixed-shape
    driver it replaced. Everything state-kind-specific — pool layout,
    admission scatter, program construction, donation, SPMD placement —
    lives behind ``self.state`` (models.decode_state).
    """

    def __init__(self, cfg, params, policy, max_batch, cache_s, *,
                 mesh=None, kv_axis=None, paged=False, block_page=None,
                 block_budget=None, prefix_cache=True):
        self.cfg, self.params, self.policy = cfg, params, policy
        self.max_batch, self.cache_s = max_batch, cache_s
        self.mesh, self.kv_axis = mesh, kv_axis
        # Whether the state actually pages is a protocol capability:
        # decode_state_for may resolve ``paged=True`` to a contiguous
        # state (O(1) recurrent state has nothing to page).
        state_cls = decode_state_for(cfg, paged=paged)
        self.paged = state_cls.is_paged
        if self.paged:
            self.state = state_cls(
                cfg, params, policy, max_batch, cache_s, mesh=mesh,
                kv_axis=kv_axis, page=block_page, n_pages=block_budget,
                prefix_cache=prefix_cache)
        else:
            self.state = state_cls(
                cfg, params, policy, max_batch, cache_s, mesh=mesh,
                kv_axis=kv_axis)
        self.queue: deque = deque()
        self.reqs: list = [None] * max_batch
        self.lens = np.zeros(max_batch, np.int64)   # tokens held per slot
        self.ntok = np.zeros(max_batch, np.int64)   # tokens emitted per slot
        # Device-side slot state: last tokens and a 0/1 liveness vector
        # (per-slot decode positions live inside the DecodeState and are
        # donated through its step). lens/ntok above are host *mirrors*
        # maintained from scheduling events alone (never read back).
        self.last = self.state.place_tokens(
            jnp.zeros((max_batch, 1), jnp.int32))
        self.live_dev = self.state.place_tokens(
            jnp.zeros((max_batch,), jnp.int32))
        self.decode_steps = 0
        self.decode_s: list = []    # per-step *dispatch* wall time (async:
                                    # compute overlaps; see req_lat for real
                                    # latency, measured at the finish sync)
        self.admit_s: list = []     # per-wave admission (prefill) wall time
        self.req_lat: list = []     # per-request submit->done wall latency
        # ---- chunked prefill (policy.prefill_chunk > 0) ----
        # resolved chunk width: 0 keeps the monolithic wave path, either
        # because the policy asked for it or because this pool cannot
        # chunk (a protocol capability: sharded/windowed paged pools
        # admit monolithically). Families round the requested budget up
        # to their invariant unit (ssm: cfg.ssm_chunk) so chunk
        # boundaries keep the fp summation order admission-invariant.
        self.chunk_c = (self.state.chunk_width(policy.prefill_chunk)
                        if policy.prefill_chunk
                        and self.state.supports_chunked() else 0)
        self.prefilling: dict = {}  # slot -> (Request, cursor tokens cached)
        self.chunk_s: list = []     # per-chunk *dispatch* wall time (async,
                                    # like decode_s; real first-token latency
                                    # is ttft below)
        self.ttft: list = []        # submit -> first-token-dispatch wall
                                    # time, sampled at scheduling events only
        self.peak_logical = 0       # max summed live tokens (paged bench)
        self.peak_pages = 0         # max physical pages in use
        self._toks: dict = {}       # slot -> [(B,1) / (B,W) token arrays]
        # ---- speculative decoding (policy.spec_k >= 2; Server wires it
        # per group through enable_spec) ----
        self.spec_k = 0             # 0 = plain one-token decode
        self.rem_dev = None         # (B,) int32 device emission budgets
        self._bursts = np.zeros(max_batch, np.int64)  # bursts per occupant
        self.spec_bursts = 0        # finished-request burst total
        self.spec_drafted = 0       # draft tokens proposed
        self.spec_accepted = 0      # draft tokens accepted by verify
        self.spec_rolled_back = 0   # draft tokens rolled back
        # ---- fault tolerance / lifecycle ----
        self.injector = None         # FaultInjector (Server threads it)
        self.base_policy = policy    # restore target for the ladder
        self.base_chunk = self.chunk_c
        self.degradable = False      # named in Server's --degrade-groups
        self.degraded = 0            # ladder rung applied to this group
        self.cancelled = 0
        self.deadline_missed = 0
        self.quarantined = 0
        self.step_faults = 0
        self.requeued = 0            # step-fault victims re-queued
        self.shed = 0                # requests dropped as unservable
        self.admit_retries = 0
        self._admit_fail = 0         # consecutive nothing-in-flight fails
        self._admit_pressure = False  # admission rejected this tick

    # --------------------------------------------- lifecycle / fault paths

    @hot_path
    def reap(self, now=None):
        """Request-lifecycle sweep, once per scheduler tick: drop
        cancelled and deadline-expired requests. Queued requests hold no
        pool state, so dropping them is free; a mid-prefill slot releases
        its reservation (pages, prefix refs, table row) through
        ``abort_chunk``; a decoding slot releases through the same abort
        path a quarantine uses. All host bookkeeping plus async device
        parking — the sweep that DOES sync runs only on the abort
        events themselves, never on the fault-free tick."""
        now = time.perf_counter() if now is None else now

        def expired(r):
            if r.cancel_requested:
                return "cancelled"
            if r.deadline_s is not None and \
                    now - r.t_submit > r.deadline_s:
                return "deadline"
            return None

        if self.queue and any(expired(r) for r in self.queue):
            kept: deque = deque()
            for r in self.queue:
                why = expired(r)
                if why is None:
                    kept.append(r)
                else:
                    self._finish_host(r, why)
            self.queue = kept
        for j in list(self.prefilling):
            why = expired(self.prefilling[j][0])
            if why is not None:
                r, _ = self.prefilling.pop(j)
                self.state.abort_chunk(j)
                self._finish_host(r, why)
                self.sweep()
        for j in range(self.max_batch):
            if self.reqs[j] is not None:
                why = expired(self.reqs[j])
                if why is not None:
                    self._abort_slot(j, why)

    def _finish_host(self, r, reason):
        """Terminal bookkeeping for a request stopped WITHOUT its tokens
        materializing (cancel/deadline/quarantine/shed): no req_lat
        sample — latency percentiles describe served traffic only."""
        r.finish_reason = reason
        r.t_done = time.perf_counter()
        if reason == "cancelled":
            self.cancelled += 1
        elif reason == "deadline":
            self.deadline_missed += 1
        elif reason == "quarantined":
            self.quarantined += 1

    def _abort_slot(self, j, reason):
        """Release a decoding slot without materializing its tokens:
        free + park the slot, reset its state (paged pools decref its
        pages), then run the invariant sweep."""
        self._bump_peaks()
        r = self.reqs[j]
        self._toks.pop(j, None)
        self.reqs[j] = None
        self.live_dev = self.live_dev.at[j].set(0)
        if self.rem_dev is not None:
            self.rem_dev = self.rem_dev.at[j].set(0)
        self._bursts[j] = 0
        self.state.reset_slots([j])
        self._finish_host(r, reason)
        self.sweep()

    def sweep(self):
        """Post-fault invariant sweep: refcount conservation, no orphaned
        block-table entries, freed slots parked at position zero —
        everything the pool holds is accounted to a live request or a
        cache entry. Runs after every quarantine/abort/recovery (and in
        tests after every chaos storm); deliberately NOT on the
        fault-free hot path, because it syncs on positions/tables."""
        occupied = {j for j in range(self.max_batch)
                    if self.reqs[j] is not None} | set(self.prefilling)
        self.state.check_integrity(occupied)

    def _admit_backoff(self) -> bool:
        """The one bounded-retry policy for a rejected admission (both
        admission modes' OutOfBlocks paths land here). In-flight work
        means pages WILL free: retry next tick, no sleep, reset the
        failure budget. Nothing in flight means no page can ever free on
        its own: retry MAX_ADMIT_RETRIES times with exponential backoff
        (transient/injected rejections clear), then shed the head
        request — it can never be admitted — instead of spinning forever
        or crashing the serve loop. Returns True if admission should be
        retried."""
        self.admit_retries += 1
        self._admit_pressure = True
        if any(q is not None for q in self.reqs) or self.prefilling:
            self._admit_fail = 0
            return True
        self._admit_fail += 1
        if self._admit_fail <= MAX_ADMIT_RETRIES:
            time.sleep(min(ADMIT_BACKOFF_S * 2 ** (self._admit_fail - 1),
                           ADMIT_BACKOFF_CAP_S))
            return True
        self._admit_fail = 0
        if self.queue:
            r = self.queue.popleft()
            self._finish_host(r, "failed")
            self.shed += 1
        return False

    def _recover_step_fault(self):
        """Self-heal after a failed decode dispatch. The donated carry
        must be presumed consumed, so ``DecodeState.recover`` drops the
        pool (paged pools also release every held page and the prefix
        cache, whose entries point into the dropped buffers). Every
        in-flight request — decoding AND mid-prefill — is a victim:
        re-queued at the head in submit order for a fresh admission, up
        to MAX_STEP_RETRIES re-serves each (a request that keeps killing
        the step is shed, not retried forever). Tokens emitted so far are
        dropped with the pool; re-admission replays the prompt, so a
        re-served request is token-identical to an undisturbed run."""
        victims = []
        for j in range(self.max_batch):
            if self.reqs[j] is not None:
                victims.append(self.reqs[j])
                self.reqs[j] = None
            self._toks.pop(j, None)
        for j in sorted(self.prefilling):
            victims.append(self.prefilling[j][0])
        self.prefilling.clear()
        self.state.recover()
        self.last = self.state.place_tokens(
            jnp.zeros((self.max_batch, 1), jnp.int32))
        self.live_dev = self.state.place_tokens(
            jnp.zeros((self.max_batch,), jnp.int32))
        if self.rem_dev is not None:
            self.rem_dev = self.state.place_tokens(
                jnp.zeros((self.max_batch,), jnp.int32))
        self.lens[:] = 0
        self.ntok[:] = 0
        self._bursts[:] = 0
        for r in sorted(victims, key=lambda v: v.t_submit, reverse=True):
            r.retries += 1
            if r.retries > MAX_STEP_RETRIES:
                self._finish_host(r, "failed")
                self.shed += 1
            else:
                r.out.clear()
                r.t_first = 0.0
                self.requeued += 1
                self.queue.appendleft(r)
        self.sweep()

    def under_pressure(self) -> bool:
        """Pool-pressure signal, sampled at scheduling events only:
        admission was rejected this tick, or a paged pool's utilization
        (allocator counters — no device reads) crossed PRESSURE_HIGH."""
        if self._admit_pressure:
            return True
        if self.paged:
            return self.state.pool_stats()["utilization"] >= PRESSURE_HIGH
        return False

    def set_degraded(self, level: int):
        """Apply one rung of the degradation ladder. L1 halves the
        prefill chunk width — smaller prefill bites per tick, so decode
        drains page-holding slots sooner; L2 additionally drops a
        *degradable* group to the policy's ``degrade_exp_backend`` (the
        paper's ~0.78%-error envelope is the license). Both directions go
        through the module-level program caches, so after the first
        application stepping up or down never recompiles."""
        level = max(0, min(2, int(level)))
        if level == self.degraded:
            return
        self.degraded = level
        if self.base_chunk:
            self.chunk_c = (self.base_chunk if level == 0 else
                            self.state.chunk_width(
                                max(1, self.base_chunk // 2)))
        pol = self.base_policy
        if level >= 2 and self.degradable and \
                pol.exp_backend != pol.degrade_exp_backend:
            pol = pol.replace(exp_backend=pol.degrade_exp_backend)
        if pol != self.policy:
            self.policy = pol
            self.state.set_policy(pol)

    def enable_spec(self, spec_k: int):
        """Opt this group into self-speculative decode: each tick runs
        ``spec_k`` draft steps under the policy's ``draft_exp_backend``
        and ONE batched exact-policy verify. Raises if the state pool
        cannot roll back a rejected burst (``supports_speculative``).
        Emission budgets move on device (``rem_dev``): the host mirrors
        advance as upper bounds and are corrected at ``_settle_slot``
        syncs, which fire only when a budget *may* have crossed — the
        zero-host-sync-per-tick discipline of the plain loop holds."""
        self.state.enable_speculative(spec_k)
        self.spec_k = int(spec_k)
        self.rem_dev = self.state.place_tokens(
            jnp.zeros((self.max_batch,), jnp.int32))

    # ------------------------------------------------------------ admission

    def _take_wave(self, free):
        """Pop an admission wave off the queue: the maximal FIFO prefix
        that shares the HEAD request's prefill bucket. A long queued
        prompt cannot inflate the whole wave's prefill shape — the wave
        closes at it and it heads the NEXT wave at its own bucket, so
        shorter requests admitted alongside it never pay its width.
        Admission order stays strictly FIFO (no overtaking: request
        identity, not arrival luck, decides service order — and solo/
        batched token identity tests pin this). Paged groups additionally
        close the wave at (a) a request whose fresh-page need PLUS the
        evictable hit pages its admission pins does not fit the pool's
        free+evictable budget (a hit on a cache-only refcount-1 page
        consumes supply too: attach pins the page, so it must not be
        counted both as "no fresh page needed" and as "reclaimable";
        admission blocks on free pages — the decode loop never does),
        and (b) a request colder than the wave's prefix-hit depth — one
        shared history shape per prefill program, and a colder row would
        drag the wave's depth down, discarding the hotter rows' cache
        hits."""
        take = []
        bucket = head_h = avail = None
        pinned = set()     # evictable hit pages already debited this wave
        while free and self.queue:
            r = self.queue[0]
            b = self.state.prefill_width(len(r.prompt))
            if bucket is not None and b > bucket:
                break
            if self.paged:
                if avail is None:
                    avail = self.state.free_with_evictable()
                need, h = self.state.admission_need(
                    r.prompt, cap_h=head_h)
                if head_h is not None and h < head_h:
                    break
                pin, pin_gids = self.state.admission_pin(r.prompt, h,
                                                         pinned)
                if not ((need + pin) <= avail).all():
                    break
                avail = avail - need - pin
                pinned.update(pin_gids)
                if head_h is None:
                    head_h = h
            if bucket is None:
                bucket = b
            take.append((free.pop(0), self.queue.popleft()))
        return take, bucket

    @hot_path
    def admit(self, admit_log=None):
        """Fill freed slots from the queue: one ragged batched prefill
        (monolithic), or per-request chunk admission when the group runs
        chunked prefill."""
        self._admit_pressure = False     # re-armed by a rejection below
        if self.injector is not None and \
                self.injector.fire("prefix.corrupt"):
            # detected prefix corruption is handled by invalidating the
            # chains — later admissions re-prefill instead of serving a
            # corrupt history (host-side cache surgery, no device sync)
            self.state.corrupt_prefix(self.injector)
        if self.chunk_c:
            return self.admit_chunked(admit_log)
        free = [j for j in range(self.max_batch) if self.reqs[j] is None]
        take, sp = self._take_wave(free)
        if not take:
            if free and self.queue and not self.prefilling and \
                    all(q is None for q in self.reqs):
                # free slots, a queued request, and NOTHING in flight —
                # yet the wave gate still couldn't take the head: its
                # page need exceeds anything the pool can ever supply.
                # Route through the bounded-retry policy (retry clears
                # transient/injected shortfalls, then shed) instead of
                # spinning the drain loop forever on an unservable head.
                self._admit_backoff()
            return
        slots = np.array([j for j, _ in take])
        # prefill always runs at the full pool width so admitting 1 or
        # max_batch requests hits the same executable per length bucket;
        # rows without an admitted request are dummies (length-1, ignored).
        toks = np.zeros((self.max_batch, sp), np.int32)
        plens = np.ones(self.max_batch, np.int32)
        for j, r in take:
            toks[j, :len(r.prompt)] = r.prompt
            plens[j] = len(r.prompt)
        full = len(take) == self.max_batch
        uniform = (full and all(len(r.prompt) == sp for _, r in take)
                   and self.policy.kernel_backend != "pallas")
        # uniform exact-bucket wave: no padding exists, skip the mask.
        # (Not under a pallas policy: the ragged path demotes pallas
        # flash-attention to the reference scan, so the fast path would
        # prefill through a different implementation than solo serving
        # and could flip a near-tie greedy argmax.)
        t0 = time.perf_counter()
        try:
            first = self.state.prefill_into(slots, toks, plens, full=full,
                                            uniform=uniform)
        except OutOfBlocks:
            # The admission gate debits fresh need AND pinned evictable
            # supply per row, so absent injected faults this is
            # unreachable by construction — but a failed allocation must
            # never crash the server. prefill_into released every page
            # the wave held; re-queue it in FIFO order and let the one
            # bounded-retry policy decide (retry next tick with work in
            # flight; bounded backoff then shed with nothing in flight).
            for _, r in reversed(take):
                self.queue.appendleft(r)
            self._admit_backoff()
            return
        jax.block_until_ready(first)
        self._admit_fail = 0
        self.admit_s.append(time.perf_counter() - t0)
        if full:
            self.last = first
        else:
            self.last = self.last.at[slots].set(first[slots])
        # one batched device-side liveness update per admission wave
        self.live_dev = self.live_dev.at[jnp.asarray(slots)].set(1)
        if self.spec_k:
            # seed the device emission budget (tokens after the first);
            # verify bursts decrement it by the true acceptance length
            self.rem_dev = self.rem_dev.at[jnp.asarray(slots)].set(
                jnp.asarray([r.max_new - 1 for _, r in take], jnp.int32))
        now = time.perf_counter()
        for j, r in take:
            self.reqs[j] = r
            self.lens[j] = len(r.prompt)
            self.ntok[j] = 1
            self._toks[j] = [first]
            r.t_first = now
            self.ttft.append(now - r.t_submit)
            if admit_log is not None:
                admit_log.append(r.rid)
            if self.ntok[j] >= r.max_new:
                self._finish(j, "max_new")
        self._bump_peaks()

    # --------------------------------------------------- chunked admission

    @hot_path
    def admit_chunked(self, admit_log=None):
        """Begin chunked admission: one queued request per freed slot,
        strictly FIFO. No wave bucketing — admission is per-request, so a
        long prompt at the head claims its own slot and streams across
        ticks while the next tick admits the short request behind it into
        another slot. Paged pools reserve the slot's pages (and attach
        its own prefix-cache hits) in ``begin_chunk``; admission blocks
        on pages — the chunk/decode loop never does."""
        free = [j for j in range(self.max_batch)
                if self.reqs[j] is None and j not in self.prefilling]
        while free and self.queue:
            r = self.queue[0]
            j = free[0]
            try:
                cur = self.state.begin_chunk(j, r.prompt, len(r.prompt))
                try:
                    # the slot now holds its full reservation; it is
                    # released only by _chunk_done -> eventual finish, by
                    # reap/abort_chunk, or — if publishing the slot to
                    # the prefilling map itself fails — right here.
                    self.prefilling[j] = (self.queue.popleft(), cur)
                except BaseException:
                    self.state.abort_chunk(j)
                    raise
            except OutOfBlocks:
                # pool exhausted (or an injected admission fault):
                # begin_chunk released anything it held; the one
                # bounded-retry policy decides — retry next tick with
                # work in flight, bounded backoff then shed the head
                # with nothing in flight (it can never be admitted).
                if self._admit_backoff():
                    break
                continue             # head was shed; try the next request
            free.pop(0)
            self._admit_fail = 0
            if admit_log is not None:
                admit_log.append(r.rid)
        self._bump_peaks()

    @hot_path
    def prefill_chunk_once(self):
        """Advance every mid-prefill slot by ONE bounded chunk — the
        at-most-one-prefill-chunk half of the engine tick (no-op when
        nothing is prefilling). One fixed-shape (pool, chunk_c) program
        call per tick: each prefilling row contributes its next
        ``clens[j] <= chunk_c`` prompt tokens at its cursor; every other
        row rides along inert (``clens == 0``). Fully async — the chunk
        is dispatched, never synced (chunk_s records dispatch wall time,
        exactly like decode_s), so the host runs ahead and XLA pipelines
        chunk and decode steps back to back."""
        if not self.prefilling:
            return
        if self.injector is not None and \
                self.injector.fire("chunk.delay"):
            time.sleep(self.injector.delay_s)   # straggler chunk
        toks = np.zeros((self.max_batch, self.chunk_c), np.int32)
        offs = np.zeros(self.max_batch, np.int32)
        clens = np.zeros(self.max_batch, np.int32)
        done = []
        for j in list(self.prefilling):
            r, cur = self.prefilling[j]
            n = min(self.chunk_c, len(r.prompt) - cur)
            toks[j, :n] = r.prompt[cur:cur + n]
            offs[j] = cur
            clens[j] = n
            if cur + n >= len(r.prompt):
                done.append(j)
            else:
                self.prefilling[j] = (r, cur + n)
        t0 = time.perf_counter()
        first = self.state.prefill_chunk_into(toks, offs, clens)
        self.chunk_s.append(time.perf_counter() - t0)
        if done:
            self._chunk_done(done, first)

    @hot_path
    def _chunk_done(self, done, first):
        """Completion dispatch for slots whose prompt finished this
        chunk: flip them live and seed decode — all device-async (the
        chunk program already pinned positions and wrote the state; the
        only device work here is the batched last-token/liveness update).
        TTFT is sampled here, at the scheduling event, not at a sync —
        the zero-host-sync discipline of the decode loop holds on the
        chunk-step path too."""
        sl = jnp.asarray(done)
        self.last = self.last.at[sl].set(first[sl])
        self.live_dev = self.live_dev.at[sl].set(1)
        if self.spec_k:
            self.rem_dev = self.rem_dev.at[sl].set(jnp.asarray(
                [self.prefilling[j][0].max_new - 1 for j in done],
                jnp.int32))
        now = time.perf_counter()
        for j in done:
            r, _ = self.prefilling.pop(j)
            self.reqs[j] = r
            self.lens[j] = len(r.prompt)
            self.ntok[j] = 1
            self._toks[j] = [first]
            r.t_first = now
            self.ttft.append(now - r.t_submit)
            self.state.finish_chunk(j, r.prompt, len(r.prompt))
            if self.ntok[j] >= r.max_new:
                self._finish(j, "max_new")
        self._bump_peaks()

    def _bump_peaks(self):
        """Track oversubscription highs (paged pools only): summed live
        logical tokens vs physical pages actually held."""
        if not self.paged:
            return
        logical = int(sum(self.lens[j] for j in range(self.max_batch)
                          if self.reqs[j] is not None))
        self.peak_logical = max(self.peak_logical, logical)
        self.peak_pages = max(self.peak_pages, self.state.alloc.n_used())

    # --------------------------------------------------------------- decode

    @hot_path
    def decode_once(self):
        """One batched decode step over the live slots (no-op when idle)."""
        cap = self.state.max_len()
        if cap is not None:
            # a linear cache is exhausted when the next write would fall
            # past the last slot — stop the request instead of letting a
            # clamped write silently overwrite the final cache row.
            # (Recurrent and ring-buffer state reports no cap.)
            for j in range(self.max_batch):
                if self.reqs[j] is not None and self.lens[j] >= cap:
                    self._finish(j, "length_cap")
        live = [j for j in range(self.max_batch) if self.reqs[j] is not None]
        if not live:
            return
        if self.injector is not None and \
                self.injector.fire("decode.poison"):
            # NaN one live slot's private state BEFORE the step: the
            # decode program's finite-logits sentinel must absorb it
            self.state.poison_slot(self.injector.choose(live))
        # dead slots decode their stale token over zeroed/parked state:
        # harmless (the slot has no request, and admission overwrites the
        # slot's state before it is read again). Positions live on device
        # (live slots advance by +1 inside the donated program), so the
        # hot loop ships nothing host->device and syncs on nothing.
        t0 = time.perf_counter()
        try:
            if self.injector is not None and \
                    self.injector.fire("decode.step_error"):
                raise InjectedFault("decode dispatch failed")
            nxt = self.state.step(self.last, self.live_dev)
        except Exception:
            # A raised decode dispatch consumed the donated carry (real
            # async XLA failures usually surface at the finish-time sync
            # instead; the injected fault exercises the same recovery):
            # rebuild the pool and re-queue the victims.
            self.step_faults += 1
            self._recover_step_fault()
            return
        self.last = nxt
        self.decode_s.append(time.perf_counter() - t0)
        self.decode_steps += 1
        for j in live:
            self.lens[j] += 1
            self.ntok[j] += 1
            self._toks[j].append(nxt)
            if self.ntok[j] >= self.reqs[j].max_new:
                self._finish(j, "max_new")

    @hot_path
    def decode_spec_once(self):
        """One speculative decode burst over the live slots (no-op when
        idle): snapshot, ``spec_k`` draft steps under the draft policy,
        ONE batched exact-policy verify that accepts the longest agreeing
        prefix + 1 bonus token and folds the rollback into the device
        carry. The burst is fully async — acceptance lengths never reach
        the host; the mirrors below advance by the burst width W as
        UPPER bounds, and a mirror crossing its budget routes through
        the one ``_settle_slot`` sync, which either finishes the request
        or restores exact mirrors. Every emitted token is an exact-policy
        argmax, so (scan verify) greedy output is token-identical to the
        plain loop."""
        live = [j for j in range(self.max_batch) if self.reqs[j] is not None]
        if not live:
            return
        if self.injector is not None and \
                self.injector.fire("decode.poison"):
            self.state.poison_slot(self.injector.choose(live))
        t0 = time.perf_counter()
        try:
            if self.injector is not None and \
                    self.injector.fire("decode.step_error"):
                raise InjectedFault("decode dispatch failed")
            snap = self.state.spec_snapshot()
            cand = [self.last]
            cur = self.last
            for _ in range(self.spec_k):
                cur = self.state.draft_step(cur, self.live_dev)
                cand.append(cur)
            toks = jnp.concatenate(cand, axis=1)        # (B, W)
            block, nlast, self.rem_dev = self.state.verify_step(
                toks, snap, self.rem_dev, self.live_dev)
        except Exception:
            # same recovery contract as the plain step: the donated
            # carry (and the snapshot fed to verify) must be presumed
            # consumed; rebuild the pool and re-queue the victims.
            self.step_faults += 1
            self._recover_step_fault()
            return
        self.last = nlast
        self.decode_s.append(time.perf_counter() - t0)
        self.decode_steps += 1
        cap = self.state.max_len()
        w = self.spec_k + 1
        for j in live:
            r = self.reqs[j]
            self._bursts[j] += 1
            self._toks[j].append(block)
            # upper-bound mirror advance: the true per-burst acceptance
            # m <= W lives in the device carry. Mirrors only ever
            # over-estimate, so every budget crossing lands in
            # _settle_slot — which corrects them exactly.
            self.ntok[j] = min(self.ntok[j] + w, r.max_new)
            self.lens[j] = (min(self.lens[j] + w, cap) if cap is not None
                            else self.lens[j] + w)
            if self.ntok[j] >= r.max_new or \
                    (cap is not None and self.lens[j] >= cap):
                self._settle_slot(j)

    def _settle_slot(self, j):
        """A speculative slot whose upper-bound mirrors crossed its
        emission budget (max_new) or the linear cache cap: ONE
        device->host sync materializes the slot's real token column
        (PAD-filtered). If the budget truly is exhausted the request
        finishes through the normal path; otherwise the mirrors are
        corrected to exact values and the slot keeps decoding. Each
        settle-and-continue makes >= 1 token of progress per following
        burst (device clamps guarantee m >= 1 while budget and cap
        room remain), so settling cannot spin."""
        r = self.reqs[j]
        col = np.asarray(jnp.concatenate(self._toks[j], axis=1))[j]
        col = col[col != SPEC_PAD]
        n = int(col.size)
        pos = len(r.prompt) + n - 1     # cache rows the slot holds
        cap = self.state.max_len()
        if (col < 0).any() or n >= r.max_new:
            self._finish(j, "max_new")  # quarantine is decided inside
        elif cap is not None and pos >= cap:
            self._finish(j, "length_cap")
        else:
            self.ntok[j] = n
            self.lens[j] = pos

    @hot_path
    def _finish(self, j, reason):
        # logical footprint and held pages grow monotonically between
        # scheduling events, so sampling the peak just before a slot
        # releases (plus at admission/stats) is exact — and keeps the
        # decode hot loop free of per-step host accounting.
        self._bump_peaks()
        r = self.reqs[j]
        # one device->host sync per finished request: gather its column
        # from the logged per-step argmax vectors / per-burst accepted
        # blocks (speculative groups; SPEC_PAD marks lanes past each
        # burst's accepted length and is filtered out here).
        toks = np.asarray(jnp.concatenate(self._toks.pop(j), axis=1))[j]
        toks = toks[toks != SPEC_PAD]
        if self.spec_k:
            b = int(self._bursts[j])
            self._bursts[j] = 0
            self.spec_bursts += b
            self.spec_drafted += b * self.spec_k
            # every burst that emitted anything spent one bonus token;
            # the rest of the column is accepted draft proposals
            acc = min(max(0, len(toks) - 1 - b), b * self.spec_k)
            self.spec_accepted += acc
            self.spec_rolled_back += b * self.spec_k - acc
            self.rem_dev = self.rem_dev.at[j].set(0)
        if (toks < 0).any():
            # the decode programs' sticky finite-logits sentinel: some
            # step saw non-finite logits for this row. Quarantine — never
            # stream the garbage — and scrub the slot (deep zero, not a
            # plain reset: surviving NaN rows would contaminate the next
            # occupant through additively-masked attention). Detection
            # costs nothing extra: the token column was already
            # materialized here.
            self.reqs[j] = None
            self.live_dev = self.live_dev.at[j].set(0)
            self.state.scrub_slot(j)
            self._finish_host(r, "quarantined")
            self.sweep()
            return
        r.out.extend(int(t) for t in toks)
        r.finish_reason = reason
        r.t_done = time.perf_counter()   # after the sync: true completion
        self.req_lat.append(r.t_done - r.t_submit)
        self.reqs[j] = None          # slot freed; next admit() reuses it
        # park the slot device-side: live=0 excludes it from position
        # advance, and the state resets the slot (recurrent h/conv is
        # read unconditionally — a stale occupant must not bleed).
        self.live_dev = self.live_dev.at[j].set(0)
        self.state.reset_slots([j])

    @property
    def busy(self) -> bool:
        return (bool(self.queue) or bool(self.prefilling)
                or any(r is not None for r in self.reqs))


class Server:
    """Slot-level continuous-batching server.

    One ExecPolicy per *group* (default: a single group from the usual
    resolution chain), each with its own ``max_batch``-slot state pool and
    exactly one decode executable. ``run(requests)`` drives admission and
    decode until every request is finished.

    Every decoding family serves through the same engine: the per-slot
    state is a ``models.decode_state.DecodeState`` — a KV cache for the
    transformer families, per-layer recurrent snapshots for ssm, a mixed
    per-period state for hybrid — and the scheduler only ever talks to
    that protocol.
    """

    def __init__(self, cfg, params, *, max_batch=4, max_seq=512, mesh=None,
                 policy: ExecPolicy | None = None,
                 policy_groups: Optional[dict] = None,
                 kv_mode: str = "auto", paged: bool = False,
                 block_page: Optional[int] = None,
                 block_budget: Optional[int] = None,
                 prefix_cache: bool = True,
                 injector: Optional[FaultInjector] = None,
                 deadline_s: Optional[float] = None,
                 degrade_groups=(), spec_groups=None):
        # raises for encoder-only archs; under --paged this resolves the
        # paged state class so the seq-sharding capability probe below
        # reflects what will actually serve
        state_cls = decode_state_for(cfg, paged=paged)
        self.cfg, self.params = cfg, params
        self.max_batch, self.max_seq = max_batch, max_seq
        self.paged = state_cls.is_paged
        self.mesh = mesh or make_host_mesh()
        self.policy = policy if policy is not None else resolve_policy(cfg)
        if self.policy.autotune or (policy_groups and any(
                p.autotune for p in policy_groups.values())):
            # warm the block-size tuner from the on-disk cache: a restart
            # on the same device kind reuses every previously-timed winner
            # instead of re-timing candidates on the first wave.
            from repro.kernels import dispatch as _dispatch
            n = _dispatch.load_autotune_cache()
            if n:
                print(f"[serve] autotune: {n} block-size winners loaded "
                      f"from {_dispatch.autotune_cache_path()}")
        self.cache_s = min(max_seq, cfg.sliding_window or max_seq)
        # Serve-loop SPMD wiring: when the state kind supports it (a
        # capability probed via the DecodeState protocol — linear KV
        # caches only) and the cache placement rules report a
        # sequence-sharded decode cache on this mesh, pallas-backend
        # groups route their decode step through the fused sharded path
        # (one shard_map program per group, built once here at startup)
        # instead of GSPMD-lowering the unsharded program.
        self.kv_axis = None
        if state_cls.supports_seq_sharding(cfg):
            from repro.distributed.sharding import decode_kv_axis
            ax = decode_kv_axis(cfg, self.mesh, max_batch, kv_mode=kv_mode)
            if (ax is not None and self.mesh.shape[ax] > 1
                    and self.cache_s % self.mesh.shape[ax] == 0):
                self.kv_axis = ax
        if self.paged and self.kv_axis is not None:
            # a sharded paged pool needs the page count per slot to split
            # evenly over the shards; pin the page size up front (the
            # autotuner must not pick one that breaks divisibility).
            nsh = self.mesh.shape[self.kv_axis]
            page_hint = int(block_page or self.policy.block_page)
            ns = -(-self.cache_s // page_hint)
            if ns % nsh != 0:
                self.kv_axis = None
            elif block_page is None:
                block_page = page_hint
        groups = dict(policy_groups) if policy_groups else {}
        if "default" not in groups:
            groups["default"] = self.policy
        self.policy_groups = groups
        self._groups = {
            name: _Group(cfg, params, pol, max_batch, self.cache_s,
                         mesh=self.mesh,
                         kv_axis=(self.kv_axis
                                  if pol.kernel_backend == "pallas"
                                  else None),
                         paged=paged, block_page=block_page,
                         block_budget=block_budget,
                         prefix_cache=prefix_cache)
            for name, pol in groups.items()}
        self.admit_log: list = []    # rids in admission order (tests/debug)
        # ---- fault tolerance / lifecycle ----
        self.injector = injector
        self.deadline_s = deadline_s     # default TTL for submitted requests
        degrade = set(degrade_groups or ())
        unknown = degrade - set(self._groups)
        if unknown:
            raise ValueError(f"unknown degrade group(s) {sorted(unknown)}; "
                             f"have {sorted(self._groups)}")
        for name, g in self._groups.items():
            g.degradable = name in degrade
            if injector is not None:
                g.injector = injector
                g.state.set_injector(injector)
        # Speculative decoding is per-group opt-in, twice over: the
        # group's policy must ask for it (spec_k >= 2) AND — when
        # --spec-groups names groups — the group must be named. With
        # spec_groups=None every spec_k group speculates. Enabling
        # raises for pools that cannot roll back a rejected burst
        # (ring-buffer KV, sharded pools, vlm extras).
        spec = None if spec_groups is None else set(spec_groups)
        if spec is not None:
            unknown = spec - set(self._groups)
            if unknown:
                raise ValueError(
                    f"unknown spec group(s) {sorted(unknown)}; "
                    f"have {sorted(self._groups)}")
        for name, g in self._groups.items():
            if spec is not None and name in spec and g.policy.spec_k < 2:
                raise ValueError(
                    f"group {name} named in spec_groups but its policy "
                    f"has spec_k={g.policy.spec_k} (need >= 2)")
            if g.policy.spec_k >= 2 and (spec is None or name in spec):
                g.enable_spec(g.policy.spec_k)
        # The ladder is strictly opt-in: with no --degrade-groups the
        # engine never trades chunk width or numerics for pressure —
        # tight paged pools run at high utilization as a matter of
        # course, and an un-opted operator gets exactly the configured
        # schedule (the chunked-prefill identity tests pin chunk_c).
        self._degrade_enabled = bool(degrade)
        self.degrade_level = 0
        self._pressure_ticks = 0
        self._clear_ticks = 0

    # ------------------------------------------------------------ scheduling

    def submit(self, r: Request) -> None:
        if r.group not in self._groups:
            raise ValueError(f"unknown policy group {r.group!r}; "
                             f"have {sorted(self._groups)}")
        plen = len(r.prompt)
        if plen < 1:
            raise ValueError(f"request {r.rid}: empty prompt")
        if plen > self.cache_s:
            raise ValueError(
                f"request {r.rid}: prompt of {plen} tokens exceeds the "
                f"cache capacity ({self.cache_s})")
        if r.max_new < 1:
            raise ValueError(f"request {r.rid}: max_new must be >= 1")
        if r.deadline_s is None:
            r.deadline_s = self.deadline_s
        r.t_submit = time.perf_counter()
        self._groups[r.group].queue.append(r)

    def cancel(self, rid: int) -> bool:
        """Cooperative cancellation by request id: flag the request
        wherever it lives (queued, mid-prefill or decoding); the next
        tick's reap drops it and releases whatever it holds. Returns
        False for an unknown or already-finished rid."""
        for g in self._groups.values():
            for r in g.queue:
                if r.rid == rid:
                    r.cancel()
                    return True
            for r in g.reqs:
                if r is not None and r.rid == rid:
                    r.cancel()
                    return True
            for r, _ in g.prefilling.values():
                if r.rid == rid:
                    r.cancel()
                    return True
        return False

    @hot_path
    def step(self) -> bool:
        """One scheduler tick: reap cancelled/expired requests, admit
        into freed slots, evaluate the degradation ladder, then (chunked
        groups) at most one bounded prefill chunk and one decode step
        per busy group. Chunk before decode: a prompt completing its last
        chunk goes live the same tick, so its first decode step follows
        immediately. Returns True while any work remains."""
        for g in self._groups.values():
            g.reap()
        for g in self._groups.values():
            g.admit(self.admit_log)
        self._degradation_tick()
        for g in self._groups.values():
            g.prefill_chunk_once()
        for g in self._groups.values():
            if g.spec_k:
                g.decode_spec_once()
            else:
                g.decode_once()
        return any(g.busy for g in self._groups.values())

    def _degradation_tick(self):
        """The ladder's hysteresis, from host-side pressure signals only
        (admission rejections this tick, allocator utilization):
        DEGRADE_AFTER consecutive pressured ticks escalate one rung —
        L1 halves the prefill chunk width, L2 also downgrades the
        --degrade-groups to their policy's ``degrade_exp_backend`` —
        and RESTORE_AFTER clear ticks step back down. The engine heals
        to full fidelity on its own; nothing stays degraded forever.
        Inert unless at least one group opted in via degrade_groups."""
        if not self._degrade_enabled:
            return
        pressured = any(g.under_pressure() for g in self._groups.values())
        if pressured:
            self._pressure_ticks += 1
            self._clear_ticks = 0
        else:
            self._clear_ticks += 1
            self._pressure_ticks = 0
        level = self.degrade_level
        if pressured and self._pressure_ticks >= DEGRADE_AFTER \
                and level < 2:
            level, self._pressure_ticks = level + 1, 0
        elif not pressured and self._clear_ticks >= RESTORE_AFTER \
                and level > 0:
            level, self._clear_ticks = level - 1, 0
        if level != self.degrade_level:
            self.degrade_level = level
            for g in self._groups.values():
                g.set_degraded(level)

    def drain(self) -> None:
        with self.mesh:
            while self.step():
                pass

    def run(self, requests: list[Request]) -> list[Request]:
        """Serve to completion; returns the requests with .out filled."""
        for r in requests:
            self.submit(r)
        self.drain()
        return requests

    # ------------------------------------------------------------ telemetry

    @hot_path
    def stats(self) -> dict:
        """Per-group decode-step count, request-latency tail (submit ->
        tokens materialized; measured at a real device sync, unlike the
        async per-step dispatch times), queue/prefill occupancy and TTFT.
        Everything here is assembled from host mirrors maintained at
        scheduling events — calling stats() mid-serve costs zero device
        syncs (the paged peak sample below reads allocator counters, not
        device state)."""
        out = {}
        for name, g in self._groups.items():
            lat = sorted(g.req_lat)
            ttft = sorted(g.ttft)

            def pct(xs, q):
                return xs[min(len(xs) * q // 100, len(xs) - 1)] \
                    if xs else 0.0

            out[name] = {
                "decode_steps": g.decode_steps,
                "p50_req_s": lat[len(lat) // 2] if lat else 0.0,
                "p95_req_s": pct(lat, 95),
                "admit_waves": len(g.admit_s),
                "admit_s_total": sum(g.admit_s, 0.0),
                # two-queue scheduler occupancy + chunk telemetry (the
                # monolithic path reports 0 chunks and admission-time
                # TTFT through the same keys)
                "queue_depth": len(g.queue),
                "prefilling": len(g.prefilling),
                "prefill_chunk": g.chunk_c,
                "prefill_chunks": len(g.chunk_s),
                "chunk_s_total": sum(g.chunk_s, 0.0),
                "p50_ttft_s": ttft[len(ttft) // 2] if ttft else 0.0,
                "p95_ttft_s": pct(ttft, 95),
                "policy": g.policy.describe(),
                "kv_axis": g.kv_axis,
                # ---- lifecycle / fault counters ----
                "cancelled": g.cancelled,
                "deadline_missed": g.deadline_missed,
                "quarantined": g.quarantined,
                "step_faults": g.step_faults,
                "requeued": g.requeued,
                "shed": g.shed,
                "admit_retries": g.admit_retries,
                "degraded": g.degraded,
            }
            if g.spec_k:
                # burst counters maintained at finish-time scheduling
                # events only (the burst itself never syncs acceptance)
                drafted = g.spec_drafted
                out[name].update({
                    "spec_k": g.spec_k,
                    "spec_verify": g.policy.spec_verify,
                    "spec_bursts": g.spec_bursts,
                    "spec_drafted": drafted,
                    "spec_accepted": g.spec_accepted,
                    "spec_rolled_back": g.spec_rolled_back,
                    "spec_acceptance": (g.spec_accepted / drafted
                                        if drafted else 0.0),
                })
            if g.paged:
                g._bump_peaks()          # sample mid-decode footprint
                pool = g.state.pool_stats()
                pool["peak_pages"] = g.peak_pages
                pool["peak_logical_tokens"] = g.peak_logical
                # summed live tokens the physical pool could hold if every
                # page were exclusive — >1.0 oversubscription means prefix
                # sharing is carrying logical state past physical capacity
                cap = pool["pages_allocatable"] * pool["page"]
                pool["peak_oversubscription"] = (g.peak_logical / cap
                                                 if cap else 0.0)
                out[name]["pool"] = pool
        return out

    def fault_stats(self) -> dict:
        """Chaos-harness summary: the engine's degradation level plus the
        injector's per-point seen/fired counters (empty when no injector
        is threaded). Kept out of stats(), whose keys are per-group."""
        out = {"degrade_level": self.degrade_level}
        if self.injector is not None:
            out["injector"] = self.injector.stats()
        return out

    # ----------------------------------------------------------- invariants

    def check_invariants(self):
        """Run every group's post-fault invariant sweep now: refcount
        conservation, no orphaned block-table entries, freed slots
        parked. Raises AssertionError on the first violation."""
        for g in self._groups.values():
            g.sweep()

    def assert_idle_clean(self):
        """Terminal leak check for a drained server: nothing queued or in
        flight anywhere, invariants hold, and — after dropping the prefix
        cache's own references — every paged group's allocator reports
        zero pages in use. Destructive to the prefix cache (this is a
        shutdown check); serving can continue but restarts cold."""
        for name, g in self._groups.items():
            if g.busy:
                raise AssertionError(f"group {name} still busy at "
                                     f"shutdown")
            g.sweep()
            if g.paged:
                if g.state.pcache is not None:
                    g.state.pcache.drop_all()
                used = g.state.alloc.n_used()
                if used:
                    raise AssertionError(
                        f"group {name}: {used} pages leaked")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--mixed-lengths", action="store_true",
                    help="draw prompt lengths in [4, --prompt-len] instead "
                         "of a uniform length (exercises ragged admission)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--exp-backend", default=None,
                    choices=["exact", "vexp", "vexp_hw"],
                    help="exponential backend (default: config/env)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "reference", "xla"],
                    help="kernel backend (default: config/env)")
    ap.add_argument("--policy-groups", default=None,
                    help='per-request policy groups, e.g. '
                         '"eval=exact,bulk=vexp" (requests are assigned '
                         'round-robin); omit for a single default group')
    ap.add_argument("--autotune", action="store_true",
                    help="autotune kernel block sizes per shape bucket")
    ap.add_argument("--prefill-chunk", type=int, default=None,
                    help="serving prefill chunk size in tokens (0 = "
                         "monolithic wave prefill; > 0 streams prompts "
                         "into their slots chunk by chunk, one bounded "
                         "chunk per engine tick, overlapped with decode; "
                         "families may round up — ssm to cfg.ssm_chunk)")
    ap.add_argument("--paged", action="store_true",
                    help="serve through the paged KV block pool (per-slot "
                         "block tables + refcounted allocator + shared-"
                         "prefix cache) instead of contiguous slot rows")
    ap.add_argument("--block-page", type=int, default=None,
                    help="KV page size in tokens (default: autotuned over "
                         "the decode_attention_paged candidates, or the "
                         "policy's block_page off the pallas backend)")
    ap.add_argument("--block-budget", type=int, default=None,
                    help="physical pages in the pool (default: one full "
                         "reservation per slot + per-shard scratch; set "
                         "lower to exercise prefix-sharing oversubscription)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="disable the shared-prefix block cache (paged only)")
    ap.add_argument("--shared-prefix", type=int, default=0,
                    help="give all generated requests an identical first N "
                         "tokens (exercises the paged prefix cache)")
    ap.add_argument("--deadline", type=float, default=None,
                    help="default per-request TTL in seconds (from "
                         "submit); expired requests are reaped at the "
                         "next scheduler tick and release their slot "
                         "and pages")
    ap.add_argument("--degrade-groups", default=None,
                    help='comma-separated policy groups the degradation '
                         'ladder may drop to the policy\'s '
                         'degrade_exp_backend under sustained pool '
                         'pressure, e.g. "bulk" (restored when pressure '
                         'clears)')
    ap.add_argument("--chaos", action="store_true",
                    help="thread a seeded FaultInjector through the "
                         "engine at the default chaos rates, assert "
                         "clean shutdown (zero leaked pages/slots) and "
                         "print the fault report")
    ap.add_argument("--fault-seed", type=int, default=None,
                    help=f"chaos seed (default: ${FAULT_SEED_ENV} or 0)")
    ap.add_argument("--cancel-frac", type=float, default=0.0,
                    help="cancel roughly this fraction of the submitted "
                         "requests mid-serve (exercises cooperative "
                         "cancellation)")
    ap.add_argument("--spec-k", type=int, default=None,
                    help="speculative decoding: draft tokens per decode "
                         "burst (0 = plain decode; >= 2 enables the "
                         "draft/verify loop — k cheap draft steps under "
                         "--draft-backend, then ONE batched exact-policy "
                         "verify accepting the longest agreeing prefix "
                         "+ 1 bonus token)")
    ap.add_argument("--draft-backend", default=None,
                    choices=["exact", "vexp", "vexp_hw"],
                    help="exp backend the draft steps run under "
                         "(default: vexp_hw, the paper's bit-exact RTL "
                         "model; emitted tokens always come from the "
                         "exact verify pass)")
    ap.add_argument("--spec-verify", default=None,
                    choices=["scan", "chunk"],
                    help='how verify scores the burst: "scan" replays '
                         'the exact decode step per lane (bitwise '
                         'speculative == plain, every family); "chunk" '
                         'scores all lanes in one batched pass (reads '
                         'cache + weights once per burst — the '
                         'throughput mode; KV caches only, may break '
                         'fp near-ties differently than plain decode)')
    ap.add_argument("--spec-groups", default=None,
                    help='comma-separated policy groups that speculate '
                         '(their policies need spec_k >= 2); omit to '
                         'speculate in every group whose policy asks')
    ap.add_argument("--kv-mode", default="auto",
                    choices=["auto", "seq", "batch"],
                    help='decode-cache placement: "seq" shards the KV '
                         'sequence dim over the mesh\'s model axis '
                         '(sequence-parallel fused decode); "auto" follows '
                         'distributed.sharding.cache_specs')
    ap.add_argument("--mesh-model", type=int, default=None,
                    help="model-axis size of the serving mesh (default: "
                         "all devices when --kv-mode seq, else 1)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    policy = resolve_policy(cfg, exp_backend=args.exp_backend,
                            kernel_backend=args.kernel_backend,
                            autotune=args.autotune or None,
                            prefill_chunk=args.prefill_chunk,
                            spec_k=args.spec_k,
                            draft_exp_backend=args.draft_backend,
                            spec_verify=args.spec_verify)
    groups = None
    if args.policy_groups:
        groups = parse_policy_groups(args.policy_groups, cfg, base=policy)
    print(f"[serve] policy: {policy.describe()}")
    if groups:
        for name, pol in groups.items():
            print(f"[serve]   group {name}: {pol.describe()}")
    params = api.init_params(cfg, jax.random.PRNGKey(0))
    n_model = args.mesh_model or (len(jax.devices())
                                  if args.kv_mode == "seq" else 1)
    mesh = make_host_mesh(1, n_model)
    injector = None
    if args.chaos:
        seed = (args.fault_seed if args.fault_seed is not None
                else int(os.environ.get(FAULT_SEED_ENV, "0") or "0"))
        injector = FaultInjector(seed=seed, rates=default_chaos_rates())
        print(f"[serve] chaos: seed={seed} rates={default_chaos_rates()}")
    degrade = tuple(s.strip() for s in (args.degrade_groups or "").split(",")
                    if s.strip())
    spec_groups = (tuple(s.strip() for s in args.spec_groups.split(",")
                         if s.strip())
                   if args.spec_groups is not None else None)
    server = Server(cfg, params, max_batch=args.max_batch,
                    max_seq=args.max_seq, mesh=mesh, policy=policy,
                    policy_groups=groups, kv_mode=args.kv_mode,
                    paged=args.paged, block_page=args.block_page,
                    block_budget=args.block_budget,
                    prefix_cache=not args.no_prefix_cache,
                    injector=injector, deadline_s=args.deadline,
                    degrade_groups=degrade, spec_groups=spec_groups)
    for name, g in server._groups.items():
        if g.spec_k:
            print(f"[serve] group {name}: speculative decode k={g.spec_k} "
                  f"draft={g.policy.draft_exp_backend} "
                  f"verify={g.policy.spec_verify}")
    print(f"[serve] mesh {dict(server.mesh.shape)}; sharded decode axis: "
          f"{server.kv_axis}" + ("; paged" if server.paged else ""))
    rng = np.random.default_rng(0)
    names = sorted(groups) if groups else ["default"]
    shared = rng.integers(0, cfg.vocab, (max(args.shared_prefix, 0),),
                          dtype=np.int32)
    reqs = []
    for i in range(args.requests):
        plen = (int(rng.integers(4, args.prompt_len + 1))
                if args.mixed_lengths else args.prompt_len)
        plen = max(plen, len(shared) + 1)   # >= 1 fresh suffix token
        prompt = rng.integers(0, cfg.vocab, (plen,), dtype=np.int32)
        prompt[:len(shared)] = shared
        reqs.append(Request(i, prompt, args.max_new,
                            group=names[i % len(names)]))
    t0 = time.perf_counter()
    for r in reqs:
        server.submit(r)
    if args.cancel_frac > 0:
        stride = max(1, int(round(1.0 / args.cancel_frac)))
        for r in reqs[::stride]:
            server.cancel(r.rid)
    server.drain()
    out = reqs
    dt = time.perf_counter() - t0
    ntok = sum(len(r.out) for r in out)
    ok = sum(r.finish_reason in ("max_new", "length_cap") for r in out)
    print(f"served {ok}/{len(out)} requests, {ntok} tokens in {dt:.2f}s "
          f"({ntok / dt:.1f} tok/s)")
    for name, s in server.stats().items():
        print(f"  group {name}: {s['decode_steps']} decode steps, "
              f"request latency p50 {s['p50_req_s'] * 1e3:.1f}ms "
              f"p95 {s['p95_req_s'] * 1e3:.1f}ms, "
              f"ttft p50 {s['p50_ttft_s'] * 1e3:.1f}ms "
              f"p95 {s['p95_ttft_s'] * 1e3:.1f}ms")
        if s["prefill_chunks"]:
            print(f"    chunked prefill: width={s['prefill_chunk']}, "
                  f"{s['prefill_chunks']} chunks dispatched "
                  f"({s['chunk_s_total'] * 1e3:.1f}ms host dispatch)")
        if s.get("spec_k"):
            print(f"    speculative: k={s['spec_k']} "
                  f"verify={s['spec_verify']} bursts={s['spec_bursts']} "
                  f"drafted={s['spec_drafted']} "
                  f"accepted={s['spec_accepted']} "
                  f"rolled_back={s['spec_rolled_back']} "
                  f"(acceptance {s['spec_acceptance']:.2f})")
        if "pool" in s:
            p = s["pool"]
            line = (f"    pool: page={p['page']} used {p['pages_used']}/"
                    f"{p['pages_allocatable']} peak {p['peak_pages']} "
                    f"(logical {p['peak_logical_tokens']} tok, "
                    f"oversub {p['peak_oversubscription']:.2f}x)")
            if "prefix" in p:
                line += (f", prefix hit rate "
                         f"{p['prefix']['hit_rate']:.2f}")
            print(line)
    for name, s in server.stats().items():
        dropped = (s["cancelled"] + s["deadline_missed"]
                   + s["quarantined"] + s["shed"])
        if dropped or s["step_faults"] or s["admit_retries"]:
            print(f"    lifecycle: cancelled={s['cancelled']} "
                  f"deadline={s['deadline_missed']} "
                  f"quarantined={s['quarantined']} shed={s['shed']} "
                  f"step_faults={s['step_faults']} "
                  f"requeued={s['requeued']} "
                  f"admit_retries={s['admit_retries']}")
    if args.chaos:
        server.assert_idle_clean()
        fs = server.fault_stats()
        fired = fs.get("injector", {}).get("fired", {})
        print(f"[serve] chaos clean shutdown: zero leaked pages/slots; "
              f"faults fired: {fired or 'none'}; "
              f"degrade level at exit: {fs['degrade_level']}")
    for r in out[:3]:
        print(f"  req {r.rid} [{r.group}] len={len(r.prompt)}: "
              f"{r.out[:8]}... ({r.finish_reason})")


if __name__ == "__main__":
    main()
