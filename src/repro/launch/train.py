"""Production training driver: pjit-sharded train step, checkpoint/restart,
preemption drain, straggler logging, deterministic data replay.

Usage (also callable as a library — see examples/train_end_to_end.py):

  PYTHONPATH=src python -m repro.launch.train --arch gpt2-small \
      --steps 200 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt [--mesh 1x1]
"""

from __future__ import annotations

import argparse
import functools
import time

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.models import api
from repro import optim
from repro.data import SyntheticLM, StructuredLM
from repro import ckpt as ckpt_lib
from repro.distributed import sharding as shd
from repro.ft import PreemptionGuard, StragglerDetector
from .mesh import make_host_mesh


def make_train_step(cfg, opt_cfg, accum_steps: int = 1, policy=None):
    """Production train step. accum_steps > 1 enables gradient
    accumulation (microbatching): the global batch is processed in
    `accum_steps` sequential microbatches, dividing peak activation
    memory by the same factor — required to fit large archs' train_4k
    (see EXPERIMENTS.md §Dry-run) — at unchanged math (mean of grads).

    ``policy`` (runtime.ExecPolicy) selects the exp/kernel backends for
    the whole step; None keeps the config's legacy execution fields
    (callers that want env-var resolution pass resolve_policy(cfg), as
    the CLI main() does)."""
    def grad_fn(params, batch):
        return jax.value_and_grad(
            lambda p: api.loss_fn(p, cfg, batch, policy=policy))(params)

    def train_step(params, opt_state, batch):
        if accum_steps == 1:
            loss, grads = grad_fn(params, batch)
        else:
            def micro(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grad_fn(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, g_acc, g)), None

            mbs = jax.tree.map(
                lambda x: x.reshape((accum_steps, -1) + x.shape[1:]), batch)
            zeros = jax.tree.map(jnp.zeros_like, params)
            (loss, grads), _ = jax.lax.scan(
                micro, (jnp.float32(0), zeros), mbs)
            loss = loss / accum_steps
            grads = jax.tree.map(lambda g: g / accum_steps, grads)
        new_params, new_opt, stats = optim.update(
            grads, opt_state, params, opt_cfg)
        stats["loss"] = loss
        return new_params, new_opt, stats
    return train_step


def shard_train_step(cfg, opt_cfg, mesh, *, fsdp=False, donate=True,
                     policy=None):
    """jit the train step with explicit in/out shardings for `mesh`."""
    pspecs = shd.param_specs(cfg, mesh, fsdp=fsdp)
    ospecs = shd.opt_specs(cfg, mesh, pspecs)
    bspecs = shd.batch_specs(cfg, mesh, "train")
    stat_specs = {"grad_norm": P(), "lr": P(), "clip_scale": P(),
                  "loss": P()}
    fn = make_train_step(cfg, opt_cfg, policy=policy)
    return jax.jit(
        fn,
        in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                      shd.named(mesh, bspecs)),
        out_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                       shd.named(mesh, stat_specs)),
        donate_argnums=(0, 1) if donate else ()), pspecs, ospecs, bspecs


def train(cfg, *, steps=100, batch=8, seq=256, ckpt_dir=None,
          ckpt_every=50, opt_cfg=None, mesh=None, fsdp=False,
          data="structured", log_every=10, guard=None, log=print,
          policy=None):
    """Run (or resume) a training job. Returns (params, history)."""
    opt_cfg = opt_cfg or optim.OptConfig(total_steps=steps)
    mesh = mesh or make_host_mesh()
    step_fn, pspecs, ospecs, bspecs = shard_train_step(
        cfg, opt_cfg, mesh, fsdp=fsdp, policy=policy)

    if data == "structured":
        pipe = StructuredLM(cfg.vocab, batch, seq, seed=17)
    else:
        pipe = SyntheticLM(cfg, batch, seq, seed=17)

    start_step = 0
    with mesh:
        params = api.init_params(cfg, jax.random.PRNGKey(0))
        params = jax.device_put(params, shd.named(mesh, pspecs))
        opt_state = optim.init(params, opt_cfg)
        opt_state = jax.device_put(opt_state, shd.named(mesh, ospecs))

        if ckpt_dir and (ckpt_lib.latest_step(ckpt_dir) is not None):
            flat, manifest = ckpt_lib.restore(ckpt_dir)
            tree = ckpt_lib.unflatten_like(
                flat, {"params": params, "opt": opt_state})
            params = ckpt_lib.reshard(tree["params"],
                                      shd.named(mesh, pspecs))
            opt_state = ckpt_lib.reshard(tree["opt"],
                                         shd.named(mesh, ospecs))
            start_step = manifest["step"]
            log(f"[train] resumed from step {start_step}")

        saver = (ckpt_lib.AsyncCheckpointer(ckpt_dir)
                 if ckpt_dir else None)
        guard = guard or PreemptionGuard()
        strag = StragglerDetector()
        history = []
        bsh = shd.named(mesh, bspecs)

        for step in range(start_step, steps):
            t0 = time.perf_counter()
            hb = pipe.batch(step)
            db = jax.tree.map(
                lambda a, s: jax.device_put(jnp.asarray(a), s), hb,
                {k: bsh[k] for k in hb})
            params, opt_state, stats = step_fn(params, opt_state, db)
            if step % log_every == 0 or step == steps - 1:
                loss = float(stats["loss"])
                history.append((step, loss))
                log(f"[train] step {step:5d} loss {loss:.4f} "
                    f"gnorm {float(stats['grad_norm']):.3f} "
                    f"lr {float(stats['lr']):.2e}")
            dt = time.perf_counter() - t0
            if strag.record(step, dt):
                log(f"[train] straggler step {step}: {dt:.2f}s "
                    f"(median {strag.median:.2f}s)")
            if saver and (step + 1) % ckpt_every == 0:
                saver.save_async({"params": params, "opt": opt_state},
                                 step + 1)
            if guard.should_stop:
                log(f"[train] preemption at step {step}; draining")
                if saver:
                    saver.wait()
                    ckpt_lib.save({"params": params, "opt": opt_state},
                                  ckpt_dir, step + 1)
                return params, history
        if saver:
            saver.wait()
            ckpt_lib.save({"params": params, "opt": opt_state},
                          ckpt_dir, steps)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt2-small")
    ap.add_argument("--reduced", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--fsdp", action="store_true")
    ap.add_argument("--data", default="structured",
                    choices=["structured", "uniform"])
    ap.add_argument("--exp-backend", default=None,
                    choices=["exact", "vexp", "vexp_hw"],
                    help="exponential backend (default: config/env)")
    ap.add_argument("--kernel-backend", default=None,
                    choices=["pallas", "reference", "xla"],
                    help="kernel backend (default: config/env)")
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    from repro.runtime import resolve_policy
    policy = resolve_policy(cfg, exp_backend=args.exp_backend,
                            kernel_backend=args.kernel_backend)
    print(f"[train] policy: {policy.describe()}")
    opt_cfg = optim.OptConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(1, args.steps // 20))
    train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
          ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
          opt_cfg=opt_cfg, fsdp=args.fsdp, data=args.data, policy=policy)


if __name__ == "__main__":
    main()
