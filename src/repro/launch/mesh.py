"""Production meshes. Functions only — importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init)."""

from __future__ import annotations

import jax


def _mesh_kwargs(n):
    # AxisType landed after 0.4.x; older jax meshes are implicitly "auto".
    if hasattr(jax.sharding, "AxisType"):
        return {"axis_types": (jax.sharding.AxisType.Auto,) * n}
    return {}


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 chips per pod (TPU v5e), 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_mesh_kwargs(len(shape)))


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / local runs)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"),
                         **_mesh_kwargs(2))
