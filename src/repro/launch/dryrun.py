import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede every other import (jax locks device count on first init).
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: builds the
production mesh from 512 placeholder host devices, jits the production
step (train_step with optimizer / prefill / decode) with explicit
in/out shardings, compiles, and records:

  * cost_analysis (per-device HLO FLOPs / bytes accessed),
  * memory_analysis (when the backend provides it) + analytic bytes/device,
  * the collective schedule (per-op-type byte totals parsed from the
    optimized HLO) for the roofline's collective term.

Artifacts land in benchmarks/artifacts/dryrun/<arch>__<shape>__<mesh>.json
and feed benchmarks/roofline.py and EXPERIMENTS.md.

  PYTHONPATH=src python -m repro.launch.dryrun --arch internvl2-1b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import json
import re
import time
import traceback

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import REGISTRY, SHAPES, get_config
from repro.models import api
from repro import optim
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "benchmarks", "artifacts", "dryrun")

# archs large enough to need ZeRO-3 parameter sharding on the data axis
FSDP_ARCHS = {"command-r-35b", "grok-1-314b", "dbrx-132b"}

_COLL_RE = re.compile(
    r"=\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16, "token": 0}


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in the (per-device)
    optimized HLO, keyed by op type."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        dtype, dims, op = m.groups()
        nbytes = _DTYPE_BYTES.get(dtype, 4)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[op] = out.get(op, 0) + n * nbytes
        out[op + "_count"] = out.get(op + "_count", 0) + 1
    return out


def analytic_state_bytes(cfg, mesh, fsdp: bool) -> dict:
    """Per-device bytes for params + optimizer state given the specs."""
    pspecs = shd.param_specs(cfg, mesh, fsdp=fsdp)
    shapes = jax.eval_shape(lambda: api.init_params(cfg, jax.random.PRNGKey(0)))

    def per_device(leaf, spec):
        shards = 1
        for ax in spec:
            if ax is not None:
                axes = ax if isinstance(ax, tuple) else (ax,)
                for a in axes:
                    shards *= mesh.shape[a]
        return leaf.size * leaf.dtype.itemsize / shards

    leaves = jax.tree.leaves(jax.tree.map(per_device, shapes, pspecs,
                                          is_leaf=lambda x: hasattr(x, "shape")))
    param_b = float(np.sum(leaves))
    # AdamW: two f32 moments per f32 param element
    return {"params_bytes_per_device": param_b,
            "opt_state_bytes_per_device": 2.0 * param_b,
            "total_state_bytes_per_device": 3.0 * param_b}


def build_cell(cfg, shape, mesh, fsdp: bool):
    """Returns (jitted_fn, example_inputs_as_ShapeDtypeStructs)."""
    specs = api.input_specs(cfg, shape)
    if shape.kind == "train":
        opt_cfg = optim.OptConfig(total_steps=1000)
        accum = 4 if fsdp else 1       # microbatch the biggest archs
        pspecs = shd.param_specs(cfg, mesh, fsdp=fsdp)
        ospecs = shd.opt_specs(cfg, mesh, pspecs)
        bspecs = shd.batch_specs(cfg, mesh, "train")
        bspecs = {k: bspecs[k] for k in specs["batch"]}
        stat_specs = {"grad_norm": P(), "lr": P(), "clip_scale": P(),
                      "loss": P()}

        from repro.launch.train import make_train_step
        train_step = make_train_step(cfg, opt_cfg, accum_steps=accum)

        params = jax.eval_shape(
            lambda: api.init_params(cfg, jax.random.PRNGKey(0)))
        opt_state = jax.eval_shape(lambda: optim.init(params, opt_cfg))
        fn = jax.jit(
            train_step,
            in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                          shd.named(mesh, bspecs)),
            out_shardings=(shd.named(mesh, pspecs), shd.named(mesh, ospecs),
                           shd.named(mesh, stat_specs)),
            donate_argnums=(0, 1))
        return fn, (params, opt_state, specs["batch"])

    pspecs = shd.param_specs(cfg, mesh, fsdp=fsdp)
    params = jax.eval_shape(
        lambda: api.init_params(cfg, jax.random.PRNGKey(0)))

    if shape.kind == "prefill":
        bspecs = shd.batch_specs(cfg, mesh, "prefill")
        bspecs = {k: bspecs[k] for k in specs["batch"]}
        fn = jax.jit(
            lambda p, b: api.prefill(p, cfg, b),
            in_shardings=(shd.named(mesh, pspecs), shd.named(mesh, bspecs)))
        return fn, (params, specs["batch"])

    # decode
    B = shape.global_batch
    dp_size = int(np.prod([mesh.shape[a] for a in shd.dp_axes(mesh)]))
    cspecs = shd.cache_specs(cfg, mesh, B)
    tok_spec = P(shd.dp_axes(mesh), None) if B >= dp_size else P(None, None)
    logit_spec = (P(shd.dp_axes(mesh), None, "model") if B >= dp_size
                  else P(None, None, "model"))
    fn = jax.jit(
        lambda p, t, c, pos: api.decode_step(p, cfg, t, c, pos),
        in_shardings=(shd.named(mesh, pspecs),
                      NamedSharding(mesh, tok_spec),
                      shd.named(mesh, cspecs),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, logit_spec),
                       shd.named(mesh, cspecs)),
        donate_argnums=(2,))
    return fn, (params, specs["token"], specs["cache"], specs["pos"])


def _count_unit(cfg) -> int:
    """The repeated unit for cost extrapolation: a layer, or a period for
    hybrids (tail layers approximated as fractional periods)."""
    return cfg.attn_period if cfg.family == "hybrid" else 1


def _with_units(cfg, n_units: int):
    import dataclasses
    return dataclasses.replace(cfg, n_layers=n_units * _count_unit(cfg),
                               unroll_scans=True)


def count_cell(cfg, shape, chips: int) -> dict:
    """HLO-derived FLOP/byte counts via unrolled single-device compiles.

    XLA's HloCostAnalysis counts while-loop bodies once, so the scanned
    production program under-reports by ~n_layers x. Here every internal
    scan (layers, CE chunks, FA KV blocks, SSD chunks) is unrolled at
    n_units in {1, 2} and the per-unit slope extrapolates to the full
    depth:  total = f(1) + (n_units-1) * (f(2) - f(1)).
    Single-device lowering: global FLOPs/bytes; per-chip = /chips
    (sharding-induced duplication, e.g. replicated GQA KV projections,
    is therefore *not* counted — noted in EXPERIMENTS.md).
    """
    import dataclasses
    unit = _count_unit(cfg)
    if cfg.family == "hybrid":
        total_units = cfg.n_layers / unit      # fractional tail
    else:
        total_units = cfg.n_layers
    vals = {}
    for n in (1, 2):
        c = _with_units(cfg, n)
        specs = api.input_specs(c, shape)
        if shape.kind == "train":
            opt_cfg = optim.OptConfig(total_steps=1000)

            def train_step(params, opt_state, batch, c=c):
                loss, grads = jax.value_and_grad(
                    lambda p: api.loss_fn(p, c, batch))(params)
                return optim.update(grads, opt_state, params, opt_cfg)

            params = jax.eval_shape(
                lambda c=c: api.init_params(c, jax.random.PRNGKey(0)))
            opt_state = jax.eval_shape(lambda: optim.init(params, opt_cfg))
            compiled = jax.jit(train_step).lower(
                params, opt_state, specs["batch"]).compile()
        elif shape.kind == "prefill":
            params = jax.eval_shape(
                lambda c=c: api.init_params(c, jax.random.PRNGKey(0)))
            compiled = jax.jit(
                lambda p, b, c=c: api.prefill(p, c, b)).lower(
                    params, specs["batch"]).compile()
        else:
            params = jax.eval_shape(
                lambda c=c: api.init_params(c, jax.random.PRNGKey(0)))
            compiled = jax.jit(
                lambda p, t, ca, pos, c=c: api.decode_step(p, c, t, ca, pos)
            ).lower(params, specs["token"], specs["cache"],
                    specs["pos"]).compile()
        cost = compiled.cost_analysis() or {}
        vals[n] = (float(cost.get("flops", 0)),
                   float(cost.get("bytes accessed", 0)))
    slope_f = vals[2][0] - vals[1][0]
    slope_b = vals[2][1] - vals[1][1]
    flops = vals[1][0] + slope_f * (total_units - 1)
    bytes_ = vals[1][1] + slope_b * (total_units - 1)
    return {"flops_global": flops, "bytes_global": bytes_,
            "flops_per_unit": slope_f, "bytes_per_unit": slope_b,
            "base_flops": vals[1][0], "units": total_units,
            "flops_per_chip": flops / chips,
            "bytes_per_chip": bytes_ / chips}


def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = ART_DIR, force: bool = False,
             max_attempts: int = 3, backoff_s: float = 60.0,
             now: float = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    now = time.time() if now is None else now
    attempts = 0
    if os.path.exists(path) and not force:
        with open(path) as f:
            cached = json.load(f)
        if cached.get("ok"):
            return cached
        # Bounded failure retry: a failed cell re-runs only while it has
        # attempts left AND its exponential backoff window has elapsed.
        # (The old rule was "failures always retry": one permanently
        # broken cell re-burned its full lower+compile wall time on
        # every sweep, forever, and back-to-back sweeps hammered flaky
        # cells with zero spacing.)
        attempts = int(cached.get("attempts", 1))
        if attempts >= max_attempts:
            return cached
        window = backoff_s * (2.0 ** (attempts - 1))
        if now - float(cached.get("t_attempt", 0.0)) < window:
            return cached

    # config/shape/mesh resolution inside the try: an unknown arch or
    # shape produces a bounded-retry failure record like any other
    # failure, instead of an uncached raise that dodges the backoff.
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "kind": None, "ok": False,
           "attempts": attempts + 1, "t_attempt": now}
    t0 = time.time()
    try:
        cfg = get_config(arch)
        shape = SHAPES[shape_name]
        mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
        fsdp = arch in FSDP_ARCHS
        rec.update({"mesh_shape": dict(mesh.shape), "fsdp": fsdp,
                    "kind": shape.kind})
        with mesh:
            fn, inputs = build_cell(cfg, shape, mesh, fsdp)
            lowered = fn.lower(*inputs)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
            cost = compiled.cost_analysis() or {}
            try:
                mem = compiled.memory_analysis()
                mem_d = {a: getattr(mem, a) for a in dir(mem)
                         if a.endswith("_in_bytes")} if mem else {}
            except Exception:
                mem_d = {}
            hlo = compiled.as_text()
            rec.update({
                "ok": True,
                "lower_s": round(t1 - t0, 2),
                "compile_s": round(t2 - t1, 2),
                "flops_per_device": float(cost.get("flops", -1)),
                "bytes_accessed_per_device": float(
                    cost.get("bytes accessed", -1)),
                "cost_analysis": {k: float(v) for k, v in cost.items()
                                  if isinstance(v, (int, float))},
                "memory_analysis": mem_d,
                "collectives": parse_collectives(hlo),
                "analytic_state": analytic_state_bytes(cfg, mesh, fsdp),
                "hlo_bytes": len(hlo),
            })
            print(compiled.memory_analysis())
        if mesh_name == "single":     # counts are mesh-independent
            try:
                rec["counted"] = count_cell(
                    cfg, shape, int(np.prod(list(mesh.shape.values()))))
            except Exception as e:
                rec["counted"] = {"error": f"{type(e).__name__}: {e}"}
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    status = "OK" if rec["ok"] else f"FAIL ({rec.get('error', '?')[:120]})"
    print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: {status} "
          f"({rec['wall_s']}s)")
    return rec


def all_cells():
    for arch, cfg in REGISTRY.items():
        if arch == "gpt2-small":
            continue
        for shape_name in cfg.shapes:
            yield arch, shape_name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out-dir", default=ART_DIR)
    ap.add_argument("--max-attempts", type=int, default=3,
                    help="give up on a failing cell after this many runs")
    ap.add_argument("--retry-backoff", type=float, default=60.0,
                    help="base seconds between retries of a failed cell "
                         "(doubles per attempt)")
    args = ap.parse_args()
    meshes = (["single", "multi"] if args.mesh == "both" else [args.mesh])
    fails = 0
    if args.all:
        for arch, shape_name in all_cells():
            for m in meshes:
                rec = run_cell(arch, shape_name, m, args.out_dir,
                               args.force, max_attempts=args.max_attempts,
                               backoff_s=args.retry_backoff)
                fails += 0 if rec["ok"] else 1
    else:
        for m in meshes:
            rec = run_cell(args.arch, args.shape, m, args.out_dir,
                           args.force, max_attempts=args.max_attempts,
                           backoff_s=args.retry_backoff)
            fails += 0 if rec["ok"] else 1
    if fails:
        raise SystemExit(f"{fails} cells failed")


if __name__ == "__main__":
    main()
